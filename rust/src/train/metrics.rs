//! Training/eval metric records + JSON history persistence.

use std::path::Path;

use anyhow::Result;

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub struct StepRecord {
    pub step: usize,
    pub epoch: usize,
    pub lr: f64,
    pub loss: f64,
    pub acc: f64,
}

#[derive(Clone, Debug, PartialEq)]
pub struct EvalRecord {
    pub step: usize,
    pub epoch: usize,
    pub loss: f64,
    pub top1: f64,
    pub top5: f64,
}

#[derive(Clone, Debug, Default)]
pub struct History {
    pub steps: Vec<StepRecord>,
    pub evals: Vec<EvalRecord>,
    pub wall_seconds: f64,
}

impl History {
    pub fn best_top1(&self) -> Option<f64> {
        self.evals.iter().map(|e| e.top1).fold(None, |acc, v| {
            Some(acc.map_or(v, |a: f64| a.max(v)))
        })
    }

    pub fn final_eval(&self) -> Option<&EvalRecord> {
        self.evals.last()
    }

    /// Mean train loss over the last `n` recorded steps.
    pub fn recent_loss(&self, n: usize) -> f64 {
        let tail = &self.steps[self.steps.len().saturating_sub(n)..];
        if tail.is_empty() {
            return f64::NAN;
        }
        tail.iter().map(|s| s.loss).sum::<f64>() / tail.len() as f64
    }

    pub fn to_json(&self) -> Json {
        let steps = self
            .steps
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("step", Json::num(s.step as f64)),
                    ("epoch", Json::num(s.epoch as f64)),
                    ("lr", Json::num(s.lr)),
                    ("loss", Json::num(s.loss)),
                    ("acc", Json::num(s.acc)),
                ])
            })
            .collect();
        let evals = self
            .evals
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("step", Json::num(e.step as f64)),
                    ("epoch", Json::num(e.epoch as f64)),
                    ("loss", Json::num(e.loss)),
                    ("top1", Json::num(e.top1)),
                    ("top5", Json::num(e.top5)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("steps", Json::Arr(steps)),
            ("evals", Json::Arr(evals)),
            ("wall_seconds", Json::num(self.wall_seconds)),
        ])
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<History> {
        let j = Json::parse(&std::fs::read_to_string(path)?)
            .map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
        let mut h = History::default();
        for s in j.arr_at("steps")? {
            h.steps.push(StepRecord {
                step: s.usize_at("step")?,
                epoch: s.usize_at("epoch")?,
                lr: s.get("lr").and_then(Json::as_f64).unwrap_or(0.0),
                loss: s.get("loss").and_then(Json::as_f64).unwrap_or(0.0),
                acc: s.get("acc").and_then(Json::as_f64).unwrap_or(0.0),
            });
        }
        for e in j.arr_at("evals")? {
            h.evals.push(EvalRecord {
                step: e.usize_at("step")?,
                epoch: e.usize_at("epoch")?,
                loss: e.get("loss").and_then(Json::as_f64).unwrap_or(0.0),
                top1: e.get("top1").and_then(Json::as_f64).unwrap_or(0.0),
                top5: e.get("top5").and_then(Json::as_f64).unwrap_or(0.0),
            });
        }
        h.wall_seconds = j.get("wall_seconds").and_then(Json::as_f64).unwrap_or(0.0);
        Ok(h)
    }
}

/// Top-k accuracy count from a logits row-major matrix.
pub fn topk_correct(logits: &[f32], labels: &[i32], classes: usize, k: usize, rows: usize) -> usize {
    let mut correct = 0;
    for r in 0..rows {
        let row = &logits[r * classes..(r + 1) * classes];
        let target = labels[r] as usize;
        let target_score = row[target];
        // rank = number of classes strictly better than the target
        let better = row.iter().filter(|&&v| v > target_score).count();
        if better < k {
            correct += 1;
        }
    }
    correct
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topk() {
        let logits = [0.1, 0.9, 0.0, 0.5, 0.2, 0.3]; // 2 rows x 3 classes
        let labels = [1, 0];
        assert_eq!(topk_correct(&logits, &labels, 3, 1, 2), 2);
        let labels = [0, 1];
        assert_eq!(topk_correct(&logits, &labels, 3, 1, 2), 0);
        // row0 target is rank 2 (in top-2); row1 target 0.2 is rank 3 (not).
        assert_eq!(topk_correct(&logits, &labels, 3, 2, 2), 1);
        assert_eq!(topk_correct(&logits, &labels, 3, 3, 2), 2);
    }

    #[test]
    fn topk_ignores_padded_rows() {
        let logits = [1.0, 0.0, 0.0, 1.0];
        let labels = [0, 0];
        assert_eq!(topk_correct(&logits, &labels, 2, 1, 1), 1);
    }

    #[test]
    fn history_roundtrip() {
        let dir = std::env::temp_dir().join(format!("lsq_hist_{}", std::process::id()));
        let path = dir.join("h.json");
        let mut h = History::default();
        h.steps.push(StepRecord { step: 1, epoch: 0, lr: 0.1, loss: 2.3, acc: 0.1 });
        h.evals.push(EvalRecord { step: 1, epoch: 0, loss: 2.2, top1: 12.5, top5: 50.0 });
        h.wall_seconds = 3.5;
        h.save(&path).unwrap();
        let back = History::load(&path).unwrap();
        assert_eq!(back.steps, h.steps);
        assert_eq!(back.evals, h.evals);
        assert_eq!(back.best_top1(), Some(12.5));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn recent_loss_windows() {
        let mut h = History::default();
        for i in 0..10 {
            h.steps.push(StepRecord { step: i, epoch: 0, lr: 0.1, loss: i as f64, acc: 0.0 });
        }
        assert!((h.recent_loss(2) - 8.5).abs() < 1e-12);
        assert!((h.recent_loss(100) - 4.5).abs() < 1e-12);
    }
}
