//! Training layer: LR schedules, metric history, named train state with
//! checkpointing, and the `Trainer` loop driving the AOT artifacts.

pub mod lr;
pub mod metrics;
pub mod state;
pub mod trainer;

pub use metrics::{EvalRecord, History, StepRecord};
pub use state::TrainState;
pub use trainer::{FitReport, Trainer};
