//! Training layer: LR schedules, metric history, named train state with
//! checkpointing, the pure-Rust native trainer (always available), and —
//! with `--features xla` — the `Trainer` loop driving the AOT artifacts.
//!
//! Both trainers implement [`TrainBackend`] and share one epoch loop
//! ([`fit_backend`]), so the native and XLA paths emit identical
//! [`History`] records, save the same checkpoint/config/history layout
//! under `out_dir/name/`, and are interchangeable to the coordinator.

pub mod lr;
pub mod metrics;
pub mod native;
pub mod state;
#[cfg(feature = "xla")]
pub mod trainer;

pub use metrics::{EvalRecord, History, StepRecord};
pub use native::NativeTrainer;
pub use state::TrainState;
#[cfg(feature = "xla")]
pub use trainer::Trainer;

use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::Result;

use crate::config::ExperimentConfig;
use crate::data::Loader;
use crate::tensor::Tensor;

/// Outcome of one full training run ([`fit_backend`]).
pub struct FitReport {
    /// Per-step and per-eval records of the run.
    pub history: History,
    /// Final test top-1 accuracy (%).
    pub final_top1: f64,
    /// Final test top-5 accuracy (%).
    pub final_top5: f64,
    /// Path of the saved final checkpoint.
    pub checkpoint: PathBuf,
}

/// The execution-backend contract of the training loop — the train-side
/// sibling of [`crate::runtime::Backend`]. Implemented by the XLA
/// `Trainer` (AOT artifacts) and [`NativeTrainer`] (pure-Rust backward);
/// [`fit_backend`] drives either through the paper's protocol.
pub trait TrainBackend {
    /// The experiment being run.
    fn cfg(&self) -> &ExperimentConfig;
    /// Rows per optimizer step.
    fn train_batch(&self) -> usize;
    /// Whether the loop prints per-epoch progress.
    fn verbose(&self) -> bool;
    /// Current parameter/momentum state.
    fn state(&self) -> &TrainState;
    /// Metric records accumulated so far.
    fn history(&self) -> &History;
    /// Mutable metric records (the loop appends step/eval rows).
    fn history_mut(&mut self) -> &mut History;
    /// One optimizer step on a prepared batch; returns `(loss, acc)`.
    fn step(&mut self, x: Tensor, y: Tensor, lr: f64, wd: f64) -> Result<(f64, f64)>;
    /// Full pass over the test split; returns `(loss, top1%, top5%)`.
    fn evaluate(&mut self) -> Result<(f64, f64, f64)>;
    /// Persist the current state as a checkpoint at `path`.
    fn save_checkpoint(&self, path: &Path) -> Result<()>;
}

/// The full training run per the backend's config: prefetched shuffled
/// batches, coordinator-owned LR schedule, periodic eval, then final eval +
/// checkpoint/history/config persisted under `out_dir/name/`.
pub fn fit_backend<B: TrainBackend + ?Sized>(t: &mut B) -> Result<FitReport> {
    let t0 = Instant::now();
    let cfg = t.cfg().clone();
    let batch = t.train_batch();
    let verbose = t.verbose();
    let wd = cfg.train.weight_decay;
    let max_steps = cfg.train.max_steps;
    // epochs = 0 with max_steps > 0 is valid config (step-bounded run):
    // derive just enough epochs to cover the step budget.
    let epochs = if cfg.train.epochs == 0 {
        let spe_est = (cfg.data.train_size / batch).max(1);
        ((max_steps + spe_est - 1) / spe_est).max(1)
    } else {
        cfg.train.epochs
    };
    let loader = Loader::spawn(&cfg.data, batch, epochs, cfg.train.seed, 4);
    let spe = loader.batches_per_epoch.max(1);

    let mut step_in_run = 0usize;
    let mut last_eval_epoch = usize::MAX;
    'outer: for epoch in 0..epochs {
        let mut ep_loss = 0.0;
        let mut ep_acc = 0.0;
        let mut ep_n = 0usize;
        for _ in 0..spe {
            let b = match loader.next() {
                Some(b) => b,
                None => break 'outer,
            };
            let lr = lr::lr_at(&cfg.train, spe, step_in_run);
            let (loss, acc) = t.step(b.x, b.y, lr, wd)?;
            let step = t.state().step;
            t.history_mut().steps.push(StepRecord { step, epoch, lr, loss, acc });
            ep_loss += loss;
            ep_acc += acc;
            ep_n += 1;
            step_in_run += 1;
            if max_steps > 0 && step_in_run >= max_steps {
                break 'outer;
            }
        }
        if cfg.train.eval_every > 0 && (epoch + 1) % cfg.train.eval_every == 0 {
            let (el, t1, t5) = t.evaluate()?;
            last_eval_epoch = epoch;
            let step = t.state().step;
            t.history_mut().evals.push(EvalRecord { step, epoch, loss: el, top1: t1, top5: t5 });
            if verbose {
                println!(
                    "[{}] epoch {:>3}  train loss {:.4} acc {:.3}  |  test loss {:.4} top1 {:.2}% top5 {:.2}%",
                    cfg.name,
                    epoch,
                    ep_loss / ep_n.max(1) as f64,
                    ep_acc / ep_n.max(1) as f64,
                    el,
                    t1,
                    t5
                );
            }
        } else if verbose {
            println!(
                "[{}] epoch {:>3}  train loss {:.4} acc {:.3}",
                cfg.name,
                epoch,
                ep_loss / ep_n.max(1) as f64,
                ep_acc / ep_n.max(1) as f64
            );
        }
    }

    // Final eval (unless the last epoch was just evaluated).
    let cur_step = t.state().step;
    if last_eval_epoch == usize::MAX
        || t.history().evals.last().map(|e| e.step) != Some(cur_step)
    {
        let (el, t1, t5) = t.evaluate()?;
        let step = t.state().step;
        t.history_mut().evals.push(EvalRecord {
            step,
            epoch: epochs.saturating_sub(1),
            loss: el,
            top1: t1,
            top5: t5,
        });
    }
    t.history_mut().wall_seconds = t0.elapsed().as_secs_f64();

    let out_dir = PathBuf::from(&cfg.out_dir).join(&cfg.name);
    std::fs::create_dir_all(&out_dir)?;
    let ckpt_path = out_dir.join("final.ckpt");
    t.save_checkpoint(&ckpt_path)?;
    t.history().save(&out_dir.join("history.json"))?;
    cfg.save(&out_dir.join("config.json"))?;

    let last = t.history().final_eval().cloned().unwrap();
    Ok(FitReport {
        history: t.history().clone(),
        final_top1: last.top1,
        final_top5: last.top5,
        checkpoint: ckpt_path,
    })
}
