//! Training layer: LR schedules, metric history, named train state with
//! checkpointing, and (with `--features xla`) the `Trainer` loop driving
//! the AOT artifacts.

pub mod lr;
pub mod metrics;
pub mod state;
#[cfg(feature = "xla")]
pub mod trainer;

pub use metrics::{EvalRecord, History, StepRecord};
pub use state::TrainState;
#[cfg(feature = "xla")]
pub use trainer::{FitReport, Trainer};
