//! Hand-written backward pass over the model-zoo architecture IR
//! ([`crate::runtime::native::arch`]) — the training datapath that lets
//! `cargo run -- train` reproduce the paper's central claim with no
//! XLA/PJRT.
//!
//! Training runs *fake quantization* in f32, exactly like the Python train
//! path (`python/compile/layers.py`): weights and input activations pass
//! through Eq. 1-2 elementwise, the matmul itself is fp32, and
//! full-precision master weights receive the gradients (Courbariaux et
//! al. 2015). All compute — GEMMs, im2col and its adjoint, pooling, batch
//! norm — routes through the shared kernel layer
//! ([`crate::runtime::kernels`]); this module is the *tape*: what to save
//! on the way forward, and which adjoints to chain on the way back:
//!
//! * matmul layers: `dŴ = X̂ᵀ·dY` ([`kernels::sgemm_tn`]), `dX̂ = dY·Ŵᵀ`
//!   ([`kernels::sgemm_nt`]), convolutions scatter `dX̂` back through the
//!   im2col adjoint ([`kernels::col2im`]). The whole fp32 GEMM family is
//!   SIMD-dispatched in the kernel layer (DESIGN.md §SIMD-dispatch), so
//!   training steps speed up with no change here — `sgemm`/`sgemm_tn`
//!   stay bitwise-deterministic across dispatch levels; `sgemm_nt`'s dot
//!   reduction is held to the layer's 1e-5 fp32 tolerance;
//! * quantizers: the Eq. 5 STE mask gates `dX̂`/`dŴ` onto the raw inputs,
//!   and the Eq. 3 term (or a method-ablation variant, [`Method`])
//!   reduces to the step-size gradient, scaled by the Section-2.2
//!   `g = 1/√(N·Qp)` ([`gradscale_value`]) — N is the weight count for
//!   `sw` and the trailing feature count for `sa`, mirroring
//!   `layers._quantize_pair`;
//! * batch norm trains on batch statistics ([`kernels::bn_batch_stats`],
//!   [`kernels::bn_bwd`]) and emits functional running-stat updates
//!   (momentum 0.9, eps 1e-5, as in `layers.batchnorm`).
//!
//! Every tape buffer (im2col patches, quantized operands, saved raw
//! inputs, normalized activations, ReLU masks, argmax maps) cycles
//! through the caller's [`Workspace`], so one `NativeTrainer` step
//! allocates only what it hands back — gradient tensors, the exact-size
//! logits copy, and the functional BN stat updates.
//!
//! Every formula here is checked against central differences of the
//! STE-consistent surrogate in `tests/grad_check.rs` (see
//! [`super::grad::lsq_surrogate_f64`]).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, ensure, Result};

use crate::quant::lsq::{self, grad_v_mask, qrange};
use crate::runtime::kernels::{self, Workspace};
use crate::runtime::native::arch::{self, Arch, ArchOp, BnSpec, ConvSpec, DenseSpec};
use crate::runtime::{Family, Manifest};
use crate::tensor::{numel, Tensor};

use super::grad::{gradscale_value, softmax_xent, Method};

/// BN hyper-parameters, shared with `python/compile/layers.py`.
pub const BN_MOMENTUM: f32 = 0.9;
/// BN variance epsilon (canonical value lives in the kernel layer).
pub const BN_EPS: f32 = kernels::BN_EPS;

// ---------------------------------------------------------------------------
// Activation buffer
// ---------------------------------------------------------------------------

struct Buf {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Buf {
    fn dims4(&self) -> Result<(usize, usize, usize, usize)> {
        match self.shape[..] {
            [b, h, w, c] => Ok((b, h, w, c)),
            _ => bail!("expected a 4-d NHWC activation, got shape {:?}", self.shape),
        }
    }

    fn dims2(&self) -> Result<(usize, usize)> {
        match self.shape[..] {
            [b, d] => Ok((b, d)),
            _ => bail!("expected a 2-d activation, got shape {:?}", self.shape),
        }
    }
}

// ---------------------------------------------------------------------------
// Tape
// ---------------------------------------------------------------------------

/// Saved context of one quantizer application (weights or activations).
struct QuantSave {
    /// Raw (pre-quantization) values, elementwise aligned with the
    /// gradient flowing back through the quantizer.
    raw: Vec<f32>,
    s: f32,
    qn: i64,
    qp: i64,
    gscale: f64,
    /// Gradient-slot index of the step-size parameter.
    g_idx: usize,
}

/// Conv-specific geometry needed by the im2col adjoint.
struct ConvGeom {
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    kh: usize,
    kw: usize,
    stride: usize,
}

/// One quantized (or fp32) matmul layer: conv (via im2col) or dense.
struct MatmulTape {
    m: usize,
    k: usize,
    n: usize,
    /// `X̂` in matmul layout (`m×k`): im2col output for conv, the
    /// (quantized) input matrix for dense.
    cols: Vec<f32>,
    /// `Ŵ` (`k×n`) as used in the forward.
    w_hat: Vec<f32>,
    w_gidx: usize,
    b_gidx: Option<usize>,
    /// Activation quantizer context (`None` for fp32 layers).
    aq: Option<QuantSave>,
    /// Weight quantizer context (`None` for fp32 layers).
    wq: Option<QuantSave>,
    /// Present for convolutions; `None` means dense (no col2im).
    conv: Option<ConvGeom>,
}

/// Batch-norm training context.
struct BnTape {
    ch: usize,
    /// Normalized activations `(x−μ)·inv`, pre-γβ.
    xhat: Vec<f32>,
    /// `1/√(var+eps)` per channel.
    inv: Vec<f32>,
    gamma: Vec<f32>,
    gamma_gidx: usize,
    beta_gidx: usize,
}

/// Pre-activation residual block: sub-tapes in forward order plus the
/// branch structure the backward must rejoin.
struct PreactTape {
    bn1: BnTape,
    relu1: Vec<bool>,
    proj: Option<MatmulTape>,
    conv1: MatmulTape,
    bn2: BnTape,
    relu2: Vec<bool>,
    conv2: MatmulTape,
}

enum Tape {
    Matmul(MatmulTape),
    Bn(BnTape),
    Relu(Vec<bool>),
    MaxPool2 { argmax: Vec<usize>, in_shape: [usize; 4] },
    Gap { in_shape: [usize; 4] },
    Flatten { in_shape: [usize; 4] },
    Preact(Box<PreactTape>),
}

/// One activation-quantizer statistic from a collect pass (Section 2.1):
/// the data-driven activation step init is `2·mean_abs/√qp`.
pub struct ActStat {
    /// Parameter name of the activation step (`{layer}.sa`).
    pub sa_name: String,
    /// Mean `|x|` over the layer's full (unquantized) input batch.
    pub mean_abs: f64,
    /// `Q_P` of the activation quantizer.
    pub qp: i64,
}

enum Pass<'a> {
    /// Training forward: record a tape + functional BN state updates.
    Train { tape: &'a mut Vec<Tape>, state_out: &'a mut Vec<(usize, Tensor)> },
    /// Inference forward: eval-mode BN, quantizers active, no tape.
    Eval,
    /// Section-2.1 collect pass: full-precision forward (no quantizers),
    /// batch-stat BN, record mean|x| at every activation quantizer.
    Collect { stats: &'a mut Vec<ActStat> },
}

impl Pass<'_> {
    fn is_train(&self) -> bool {
        matches!(self, Pass::Train { .. })
    }

    fn is_collect(&self) -> bool {
        matches!(self, Pass::Collect { .. })
    }
}

// ---------------------------------------------------------------------------
// Model
// ---------------------------------------------------------------------------

/// Output of one native train step's loss/gradient computation.
pub struct StepOutput {
    /// Mean softmax cross-entropy over the batch.
    pub loss: f64,
    /// Rows whose argmax logit equals the label.
    pub ncorrect: usize,
    /// Raw logits (`rows × num_classes`).
    pub logits: Vec<f32>,
    /// One gradient tensor per `Family::grad_names`, in order.
    pub grads: Vec<Tensor>,
    /// Functional BN running-stat updates as `(param index, new value)`.
    pub state_updates: Vec<(usize, Tensor)>,
}

/// A model family bound for *training*: the arch IR plus parameter/gradient
/// index maps. Unlike [`crate::runtime::native::NativeModel`] (which packs
/// weights once for serving), this holds no parameter state — every call
/// takes the current `params` so the optimizer owns the master copies —
/// and no scratch: compute scratch comes from the caller's [`Workspace`]
/// (the trainer owns one and reuses it every step).
pub struct NativeTrainModel {
    arch: Arch,
    family: String,
    method: Method,
    gscale_mode: String,
    pidx: BTreeMap<String, usize>,
    gidx: BTreeMap<String, usize>,
    grad_shapes: Vec<Vec<usize>>,
    image: usize,
    channels: usize,
    num_classes: usize,
}

impl NativeTrainModel {
    /// Bind `family`'s architecture for training under quantizer `method`
    /// and gradient-scale mode `gscale_mode` (both validated here).
    pub fn build(
        manifest: &Manifest,
        family: &str,
        method: &str,
        gscale_mode: &str,
    ) -> Result<NativeTrainModel> {
        let fam: &Family = manifest.family(family)?;
        let arch = arch::build(
            &fam.model,
            manifest.image,
            manifest.channels,
            fam.num_classes,
            fam.qbits,
        )?;
        // Resolve the method once (hot loops dispatch on the enum) and
        // fail fast on unknown gscale names.
        let method = Method::parse(method)?;
        gradscale_value(1, 1, gscale_mode)?;
        let pidx: BTreeMap<String, usize> =
            fam.param_names.iter().enumerate().map(|(i, n)| (n.clone(), i)).collect();
        let gidx: BTreeMap<String, usize> =
            fam.grad_names.iter().enumerate().map(|(i, n)| (n.clone(), i)).collect();
        let grad_shapes = fam
            .grad_names
            .iter()
            .map(|n| fam.shapes.get(n).cloned().unwrap_or_default())
            .collect();
        Ok(NativeTrainModel {
            arch,
            family: family.to_string(),
            method,
            gscale_mode: gscale_mode.to_string(),
            pidx,
            gidx,
            grad_shapes,
            image: manifest.image,
            channels: manifest.channels,
            num_classes: fam.num_classes,
        })
    }

    /// Per-image input element count.
    pub fn image_len(&self) -> usize {
        self.image * self.image * self.channels
    }

    /// Logit count per row.
    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    /// The family this model was built for.
    pub fn family(&self) -> &str {
        &self.family
    }

    fn param<'a>(&self, params: &'a [Tensor], name: &str) -> Result<&'a Tensor> {
        let i = *self
            .pidx
            .get(name)
            .ok_or_else(|| anyhow!("family {} has no parameter {name:?}", self.family))?;
        Ok(&params[i])
    }

    fn scalar(&self, params: &[Tensor], name: &str) -> Result<f32> {
        self.param(params, name)?.item_f32()
    }

    fn grad_slot(&self, name: &str) -> Result<usize> {
        self.gidx
            .get(name)
            .copied()
            .ok_or_else(|| anyhow!("parameter {name:?} has no gradient slot"))
    }

    fn check_input(&self, x: &[f32], rows: usize) -> Result<()> {
        ensure!(rows > 0, "empty batch");
        ensure!(
            x.len() == rows * self.image_len(),
            "input has {} floats, expected {} ({} rows x {})",
            x.len(),
            rows * self.image_len(),
            rows,
            self.image_len()
        );
        Ok(())
    }

    // -- forward ------------------------------------------------------------

    fn forward_pass(
        &self,
        ws: &mut Workspace,
        params: &[Tensor],
        x: &[f32],
        rows: usize,
        pass: &mut Pass,
    ) -> Result<Buf> {
        self.check_input(x, rows)?;
        let mut data = ws.take_f32_cap(x.len());
        data.extend_from_slice(x);
        let mut act = Buf {
            shape: vec![rows, self.image, self.image, self.channels],
            data,
        };
        for op in &self.arch.ops {
            act = self.apply_op(ws, params, act, op, pass)?;
        }
        ensure!(
            act.shape == [rows, self.num_classes],
            "forward produced shape {:?}, expected [{rows}, {}]",
            act.shape,
            self.num_classes
        );
        Ok(act)
    }

    fn apply_op(
        &self,
        ws: &mut Workspace,
        params: &[Tensor],
        act: Buf,
        op: &ArchOp,
        pass: &mut Pass,
    ) -> Result<Buf> {
        Ok(match op {
            ArchOp::Conv(c) => {
                let (out, t) = self.fwd_conv(ws, params, &act, c, pass)?;
                ws.recycle_f32(act.data);
                if let (Pass::Train { tape, .. }, Some(t)) = (&mut *pass, t) {
                    tape.push(Tape::Matmul(t));
                }
                out
            }
            ArchOp::Dense(d) => {
                let (out, t) = self.fwd_dense(ws, params, &act, d, pass)?;
                ws.recycle_f32(act.data);
                if let (Pass::Train { tape, .. }, Some(t)) = (&mut *pass, t) {
                    tape.push(Tape::Matmul(t));
                }
                out
            }
            ArchOp::BatchNorm(b) => {
                let (out, t) = self.fwd_bn(ws, params, act, b, pass)?;
                if let (Pass::Train { tape, .. }, Some(t)) = (&mut *pass, t) {
                    tape.push(Tape::Bn(t));
                }
                out
            }
            ArchOp::Relu => {
                let (out, mask) = fwd_relu(ws, act, pass.is_train());
                if let (Pass::Train { tape, .. }, Some(m)) = (&mut *pass, mask) {
                    tape.push(Tape::Relu(m));
                }
                out
            }
            ArchOp::MaxPool2 => {
                let (b, h, w, c) = act.dims4()?;
                let (out, argmax) = fwd_maxpool2(ws, &act, pass.is_train())?;
                ws.recycle_f32(act.data);
                if let (Pass::Train { tape, .. }, Some(a)) = (&mut *pass, argmax) {
                    tape.push(Tape::MaxPool2 { argmax: a, in_shape: [b, h, w, c] });
                }
                out
            }
            ArchOp::GlobalAvgPool => {
                let (b, h, w, c) = act.dims4()?;
                let out = fwd_gap(ws, &act)?;
                ws.recycle_f32(act.data);
                if let Pass::Train { tape, .. } = pass {
                    tape.push(Tape::Gap { in_shape: [b, h, w, c] });
                }
                out
            }
            ArchOp::Flatten => {
                let (b, h, w, c) = act.dims4()?;
                if let Pass::Train { tape, .. } = pass {
                    tape.push(Tape::Flatten { in_shape: [b, h, w, c] });
                }
                Buf { shape: vec![b, h * w * c], data: act.data }
            }
            ArchOp::Preact(p) => {
                let (out, t) = self.fwd_preact(ws, params, act, p, pass)?;
                if let (Pass::Train { tape, .. }, Some(t)) = (&mut *pass, t) {
                    tape.push(Tape::Preact(Box::new(t)));
                }
                out
            }
        })
    }

    /// Quantize one matmul operand pair for training, recording the
    /// quantizer contexts. Returns `(x_hat, w_hat, aq, wq)` — raw
    /// passthrough (and a collect stat) when `pass` is `Collect` or the
    /// layer is full precision. All returned buffers come from `ws`.
    #[allow(clippy::type_complexity, clippy::too_many_arguments)]
    fn quantize_pair(
        &self,
        ws: &mut Workspace,
        params: &[Tensor],
        name: &str,
        bits: u32,
        signed_act: bool,
        x: &[f32],
        w: &[f32],
        n_feat: usize,
        pass: &mut Pass,
    ) -> Result<(Vec<f32>, Vec<f32>, Option<QuantSave>, Option<QuantSave>)> {
        if bits >= 32 {
            return Ok((copy_into_ws(ws, x), copy_into_ws(ws, w), None, None));
        }
        let (aqn, aqp) = qrange(bits, signed_act);
        if let Pass::Collect { stats } = pass {
            let mean_abs = x.iter().map(|v| v.abs() as f64).sum::<f64>() / x.len().max(1) as f64;
            stats.push(ActStat { sa_name: format!("{name}.sa"), mean_abs, qp: aqp });
            return Ok((copy_into_ws(ws, x), copy_into_ws(ws, w), None, None));
        }
        let (wqn, wqp) = qrange(bits, true);
        let sw = self.scalar(params, &format!("{name}.sw"))?;
        let sa = self.scalar(params, &format!("{name}.sa"))?;
        ensure!(sw > 0.0 && sa > 0.0, "{name}: non-positive step size (sw={sw}, sa={sa})");
        let mut x_hat = ws.take_f32_cap(x.len());
        x_hat.extend(x.iter().map(|&v| lsq::quantize(v, sa, aqn, aqp)));
        let mut w_hat = ws.take_f32_cap(w.len());
        w_hat.extend(w.iter().map(|&v| lsq::quantize(v, sw, wqn, wqp)));
        let (aq, wq) = if pass.is_train() {
            let g_a = gradscale_value(n_feat, aqp, &self.gscale_mode)?;
            let g_w = gradscale_value(w.len(), wqp, &self.gscale_mode)?;
            (
                Some(QuantSave {
                    raw: copy_into_ws(ws, x),
                    s: sa,
                    qn: aqn,
                    qp: aqp,
                    gscale: g_a,
                    g_idx: self.grad_slot(&format!("{name}.sa"))?,
                }),
                Some(QuantSave {
                    raw: copy_into_ws(ws, w),
                    s: sw,
                    qn: wqn,
                    qp: wqp,
                    gscale: g_w,
                    g_idx: self.grad_slot(&format!("{name}.sw"))?,
                }),
            )
        } else {
            (None, None)
        };
        Ok((x_hat, w_hat, aq, wq))
    }

    fn fwd_conv(
        &self,
        ws: &mut Workspace,
        params: &[Tensor],
        act: &Buf,
        spec: &ConvSpec,
        pass: &mut Pass,
    ) -> Result<(Buf, Option<MatmulTape>)> {
        let (b, h, w, c) = act.dims4()?;
        ensure!(c == spec.in_ch, "{}: input has {c} channels, expected {}", spec.name, spec.in_ch);
        let wt = self.param(params, &format!("{}.w", spec.name))?;
        ensure!(
            wt.shape == [spec.kh, spec.kw, spec.in_ch, spec.out_ch],
            "{}.w shape {:?}",
            spec.name,
            wt.shape
        );
        let (x_hat, w_hat, aq, wq) = self.quantize_pair(
            ws,
            params,
            &spec.name,
            spec.bits,
            spec.signed_act,
            &act.data,
            wt.f32s()?,
            spec.in_ch,
            pass,
        )?;
        let k = spec.kh * spec.kw * c;
        let n = spec.out_ch;
        // Pre-size the patch buffer so the pool hands back a fitting
        // allocation (im2col re-derives the same geometry).
        let (oh, _) = kernels::same_padding(h, spec.kh, spec.stride);
        let (ow, _) = kernels::same_padding(w, spec.kw, spec.stride);
        let m = b * oh * ow;
        let mut cols = ws.take_f32_cap(m * k);
        kernels::im2col(&x_hat, 0.0, b, h, w, c, spec.kh, spec.kw, spec.stride, &mut cols);
        ws.recycle_f32(x_hat);
        let mut out = ws.take_f32_any(m * n);
        kernels::sgemm(ws, m, k, n, &cols, &w_hat, None, &mut out);
        let tape = if pass.is_train() {
            Some(MatmulTape {
                m,
                k,
                n,
                cols,
                w_hat,
                w_gidx: self.grad_slot(&format!("{}.w", spec.name))?,
                b_gidx: None,
                aq,
                wq,
                conv: Some(ConvGeom { b, h, w, c, kh: spec.kh, kw: spec.kw, stride: spec.stride }),
            })
        } else {
            ws.recycle_f32(cols);
            ws.recycle_f32(w_hat);
            None
        };
        Ok((Buf { shape: vec![b, oh, ow, n], data: out }, tape))
    }

    fn fwd_dense(
        &self,
        ws: &mut Workspace,
        params: &[Tensor],
        act: &Buf,
        spec: &DenseSpec,
        pass: &mut Pass,
    ) -> Result<(Buf, Option<MatmulTape>)> {
        let (m, d) = act.dims2()?;
        ensure!(d == spec.in_dim, "{}: input dim {d} != expected {}", spec.name, spec.in_dim);
        let wt = self.param(params, &format!("{}.w", spec.name))?;
        ensure!(
            wt.shape == [spec.in_dim, spec.out_dim],
            "{}.w shape {:?}",
            spec.name,
            wt.shape
        );
        let (x_hat, w_hat, aq, wq) = self.quantize_pair(
            ws,
            params,
            &spec.name,
            spec.bits,
            spec.signed_act,
            &act.data,
            wt.f32s()?,
            spec.in_dim,
            pass,
        )?;
        let n = spec.out_dim;
        let bias_name = format!("{}.b", spec.name);
        let bias = match self.pidx.get(&bias_name) {
            Some(&i) => {
                ensure!(params[i].numel() == n, "{bias_name} wrong length");
                Some(params[i].f32s()?)
            }
            None => None,
        };
        let mut out = ws.take_f32_any(m * n);
        kernels::sgemm(ws, m, d, n, &x_hat, &w_hat, bias, &mut out);
        let tape = if pass.is_train() {
            Some(MatmulTape {
                m,
                k: d,
                n,
                cols: x_hat,
                w_hat,
                w_gidx: self.grad_slot(&format!("{}.w", spec.name))?,
                b_gidx: bias.map(|_| self.grad_slot(&bias_name)).transpose()?,
                aq,
                wq,
                conv: None,
            })
        } else {
            ws.recycle_f32(x_hat);
            ws.recycle_f32(w_hat);
            None
        };
        Ok((Buf { shape: vec![m, n], data: out }, tape))
    }

    fn fwd_bn(
        &self,
        ws: &mut Workspace,
        params: &[Tensor],
        mut act: Buf,
        spec: &BnSpec,
        pass: &mut Pass,
    ) -> Result<(Buf, Option<BnTape>)> {
        let ch = *act.shape.last().unwrap_or(&0);
        ensure!(ch == spec.ch, "{}: {ch} channels, expected {}", spec.name, spec.ch);
        let gamma = self.param(params, &format!("{}.gamma", spec.name))?.f32s()?.to_vec();
        let beta = self.param(params, &format!("{}.beta", spec.name))?.f32s()?;
        let rows = act.data.len() / ch;
        ensure!(rows > 0, "{}: empty input", spec.name);

        let (mean, var) = if pass.is_train() || pass.is_collect() {
            // Batch statistics (biased variance, like jnp.var).
            kernels::bn_batch_stats(&act.data, ch)
        } else {
            (
                self.param(params, &format!("{}.rmean", spec.name))?.f32s()?.to_vec(),
                self.param(params, &format!("{}.rvar", spec.name))?.f32s()?.to_vec(),
            )
        };

        let train = pass.is_train();
        let inv: Vec<f32> = var.iter().map(|&v| 1.0 / (v + kernels::BN_EPS).sqrt()).collect();
        let mut xhat = if train {
            Some(ws.take_f32_cap(act.data.len()))
        } else {
            None
        };
        kernels::bn_normalize(&mut act.data, &mean, &inv, &gamma, beta, xhat.as_mut());

        let tape = if let Pass::Train { state_out, .. } = pass {
            // Functional running-stat updates (mirrors layers.batchnorm).
            let rmean_name = format!("{}.rmean", spec.name);
            let rvar_name = format!("{}.rvar", spec.name);
            let rmean = self.param(params, &rmean_name)?.f32s()?;
            let rvar = self.param(params, &rvar_name)?.f32s()?;
            let new_rmean: Vec<f32> = rmean
                .iter()
                .zip(&mean)
                .map(|(&r, &m)| BN_MOMENTUM * r + (1.0 - BN_MOMENTUM) * m)
                .collect();
            let new_rvar: Vec<f32> = rvar
                .iter()
                .zip(&var)
                .map(|(&r, &v)| BN_MOMENTUM * r + (1.0 - BN_MOMENTUM) * v)
                .collect();
            let rmean_idx = *self
                .pidx
                .get(&rmean_name)
                .ok_or_else(|| anyhow!("no param {rmean_name}"))?;
            let rvar_idx = *self
                .pidx
                .get(&rvar_name)
                .ok_or_else(|| anyhow!("no param {rvar_name}"))?;
            state_out.push((rmean_idx, Tensor::from_f32(&[ch], new_rmean)));
            state_out.push((rvar_idx, Tensor::from_f32(&[ch], new_rvar)));
            Some(BnTape {
                ch,
                xhat: xhat.unwrap(),
                inv,
                gamma,
                gamma_gidx: self.grad_slot(&format!("{}.gamma", spec.name))?,
                beta_gidx: self.grad_slot(&format!("{}.beta", spec.name))?,
            })
        } else {
            None
        };
        Ok((act, tape))
    }

    fn fwd_preact(
        &self,
        ws: &mut Workspace,
        params: &[Tensor],
        x: Buf,
        p: &arch::PreactSpec,
        pass: &mut Pass,
    ) -> Result<(Buf, Option<PreactTape>)> {
        // pre = relu(bn1(x)); shortcut from `pre` when projecting, raw x
        // otherwise (mirrors runtime::native::apply_preact). The identity
        // shortcut keeps the input alive via a workspace copy (training BN
        // normalizes in place, so it cannot borrow `x` like the eval path).
        let x_copy = if p.proj.is_none() {
            let mut data = ws.take_f32_cap(x.data.len());
            data.extend_from_slice(&x.data);
            Some(Buf { shape: x.shape.clone(), data })
        } else {
            None
        };
        let (pre, bn1_t) = self.fwd_bn(ws, params, x, &p.bn1, pass)?;
        let (pre, relu1_m) = fwd_relu(ws, pre, pass.is_train());
        let (sc, proj_t) = match &p.proj {
            Some(proj) => {
                let (sc, t) = self.fwd_conv(ws, params, &pre, proj, pass)?;
                (sc, t)
            }
            None => (x_copy.unwrap(), None),
        };
        let (h, conv1_t) = self.fwd_conv(ws, params, &pre, &p.conv1, pass)?;
        ws.recycle_f32(pre.data);
        let (h, bn2_t) = self.fwd_bn(ws, params, h, &p.bn2, pass)?;
        let (h, relu2_m) = fwd_relu(ws, h, pass.is_train());
        let (mut out, conv2_t) = self.fwd_conv(ws, params, &h, &p.conv2, pass)?;
        ws.recycle_f32(h.data);
        ensure!(
            out.shape == sc.shape,
            "residual shape mismatch: {:?} vs {:?}",
            out.shape,
            sc.shape
        );
        for (a, b) in out.data.iter_mut().zip(&sc.data) {
            *a += b;
        }
        ws.recycle_f32(sc.data);
        let tape = if pass.is_train() {
            Some(PreactTape {
                bn1: bn1_t.unwrap(),
                relu1: relu1_m.unwrap(),
                proj: proj_t,
                conv1: conv1_t.unwrap(),
                bn2: bn2_t.unwrap(),
                relu2: relu2_m.unwrap(),
                conv2: conv2_t.unwrap(),
            })
        } else {
            None
        };
        Ok((out, tape))
    }

    // -- public entry points -------------------------------------------------

    /// Inference forward (eval-mode BN, quantizers active): returns
    /// `rows × num_classes` logits. Scratch comes from `ws`.
    pub fn forward_eval(
        &self,
        ws: &mut Workspace,
        params: &[Tensor],
        x: &[f32],
        rows: usize,
    ) -> Result<Vec<f32>> {
        let out = self.forward_pass(ws, params, x, rows, &mut Pass::Eval)?;
        // Exact-size copy out; the pooled buffer stays in the workspace.
        let logits = out.data.clone();
        ws.recycle_f32(out.data);
        Ok(logits)
    }

    /// Section-2.1 collect pass over one (unaugmented) batch: runs the
    /// *unquantized* network and records mean|x| at every activation
    /// quantizer, for the `2⟨|v|⟩/√Qp` activation-step init.
    pub fn collect_act_stats(
        &self,
        ws: &mut Workspace,
        params: &[Tensor],
        x: &[f32],
        rows: usize,
    ) -> Result<Vec<ActStat>> {
        let mut stats = Vec::new();
        let mut pass = Pass::Collect { stats: &mut stats };
        let out = self.forward_pass(ws, params, x, rows, &mut pass)?;
        ws.recycle_f32(out.data);
        Ok(stats)
    }

    /// One training forward+backward on a batch: softmax cross-entropy
    /// loss, gradients for every `Family::grad_names` slot, and the
    /// functional BN state updates. All tape and gradient staging buffers
    /// cycle through `ws`.
    pub fn loss_and_grads(
        &self,
        ws: &mut Workspace,
        params: &[Tensor],
        x: &[f32],
        y: &[i32],
        rows: usize,
    ) -> Result<StepOutput> {
        ensure!(y.len() >= rows, "labels shorter than batch");
        let mut tape: Vec<Tape> = Vec::new();
        let mut state_out: Vec<(usize, Tensor)> = Vec::new();
        let logits = self.forward_pass(
            ws,
            params,
            x,
            rows,
            &mut Pass::Train { tape: &mut tape, state_out: &mut state_out },
        )?;
        let (loss, ncorrect, dlogits) = softmax_xent(&logits.data, y, self.num_classes, rows);
        let mut grads: Vec<Vec<f32>> =
            self.grad_shapes.iter().map(|s| vec![0.0f32; numel(s)]).collect();
        let mut d = Buf { shape: vec![rows, self.num_classes], data: dlogits };
        for entry in tape.iter().rev() {
            d = self.bwd_op(ws, entry, d, &mut grads)?;
        }
        ws.recycle_f32(d.data);
        recycle_tape(ws, tape);
        let grads = grads
            .into_iter()
            .zip(&self.grad_shapes)
            .map(|(g, s)| Tensor::from_f32(s, g))
            .collect();
        // Hand the caller an exact-size logits Vec and keep the pooled
        // buffer — a pool buffer escaping through StepOutput would leak
        // one pool entry per step (see NativeModel::forward).
        let out_logits = logits.data.clone();
        ws.recycle_f32(logits.data);
        Ok(StepOutput { loss, ncorrect, logits: out_logits, grads, state_updates: state_out })
    }

    // -- backward ------------------------------------------------------------

    fn bwd_op(
        &self,
        ws: &mut Workspace,
        entry: &Tape,
        dy: Buf,
        grads: &mut [Vec<f32>],
    ) -> Result<Buf> {
        Ok(match entry {
            Tape::Matmul(t) => self.bwd_matmul(ws, t, dy, grads)?,
            Tape::Bn(t) => bwd_bn(t, dy, grads)?,
            Tape::Relu(mask) => {
                let mut dy = dy;
                kernels::relu_bwd(mask, &mut dy.data);
                dy
            }
            Tape::MaxPool2 { argmax, in_shape } => {
                ensure!(dy.data.len() == argmax.len(), "maxpool backward shape");
                let mut dx = ws.take_f32_any(in_shape.iter().product());
                kernels::maxpool2_bwd(argmax, &dy.data, &mut dx);
                ws.recycle_f32(dy.data);
                Buf { shape: in_shape.to_vec(), data: dx }
            }
            Tape::Gap { in_shape } => {
                let [b, h, w, c] = *in_shape;
                ensure!(dy.data.len() == b * c, "gap backward shape");
                let mut dx = ws.take_f32_any(b * h * w * c);
                kernels::global_avg_pool_bwd(&dy.data, b, h, w, c, &mut dx);
                ws.recycle_f32(dy.data);
                Buf { shape: in_shape.to_vec(), data: dx }
            }
            Tape::Flatten { in_shape } => Buf { shape: in_shape.to_vec(), data: dy.data },
            Tape::Preact(t) => self.bwd_preact(ws, t, dy, grads)?,
        })
    }

    fn bwd_matmul(
        &self,
        ws: &mut Workspace,
        t: &MatmulTape,
        dy: Buf,
        grads: &mut [Vec<f32>],
    ) -> Result<Buf> {
        let (m, k, n) = (t.m, t.k, t.n);
        ensure!(dy.data.len() == m * n, "matmul backward: dY has wrong shape");

        // dŴ = X̂ᵀ · dY, then through the weight quantizer (Eq. 5 mask on
        // the raw weights, Eq. 3 reduction to dsw).
        let mut dw_hat = ws.take_f32_any(k * n);
        kernels::sgemm_tn(ws, m, k, n, &t.cols, &dy.data, &mut dw_hat);
        match &t.wq {
            Some(q) => {
                let mut ds = 0.0f64;
                let gw = &mut grads[t.w_gidx];
                for (i, &d) in dw_hat.iter().enumerate() {
                    let v = q.raw[i];
                    gw[i] += d * grad_v_mask(v, q.s, q.qn, q.qp);
                    ds += d as f64 * self.method.ds_term(v, q.s, q.qn, q.qp) as f64;
                }
                grads[q.g_idx][0] += (ds * q.gscale) as f32;
            }
            None => {
                let gw = &mut grads[t.w_gidx];
                for (g, &d) in gw.iter_mut().zip(&dw_hat) {
                    *g += d;
                }
            }
        }
        ws.recycle_f32(dw_hat);

        // db = column sums of dY.
        if let Some(bg) = t.b_gidx {
            let gb = &mut grads[bg];
            for i in 0..m {
                let row = &dy.data[i * n..(i + 1) * n];
                for (g, &d) in gb.iter_mut().zip(row) {
                    *g += d;
                }
            }
        }

        // dX̂ = dY · Ŵᵀ; convolutions scatter back through the im2col
        // adjoint so each input element accumulates over every patch that
        // read it.
        let mut dcols = ws.take_f32_any(m * k);
        kernels::sgemm_nt(ws, m, k, n, &dy.data, &t.w_hat, &mut dcols);
        ws.recycle_f32(dy.data);
        let (mut dxhat, in_shape): (Vec<f32>, Vec<usize>) = match &t.conv {
            Some(g) => {
                let mut dx = ws.take_f32(g.b * g.h * g.w * g.c);
                kernels::col2im(&dcols, g.b, g.h, g.w, g.c, g.kh, g.kw, g.stride, &mut dx);
                ws.recycle_f32(dcols);
                (dx, vec![g.b, g.h, g.w, g.c])
            }
            None => (dcols, vec![m, k]),
        };

        // Through the activation quantizer: dsa reduces over the *input*
        // elements (post-col2im), then the STE mask gates dX.
        if let Some(q) = &t.aq {
            let mut ds = 0.0f64;
            for (i, d) in dxhat.iter_mut().enumerate() {
                let v = q.raw[i];
                ds += *d as f64 * self.method.ds_term(v, q.s, q.qn, q.qp) as f64;
                *d *= grad_v_mask(v, q.s, q.qn, q.qp);
            }
            grads[q.g_idx][0] += (ds * q.gscale) as f32;
        }
        Ok(Buf { shape: in_shape, data: dxhat })
    }

    fn bwd_preact(
        &self,
        ws: &mut Workspace,
        t: &PreactTape,
        dy: Buf,
        grads: &mut [Vec<f32>],
    ) -> Result<Buf> {
        // Residual: dout feeds both the conv branch and the shortcut.
        let mut sc_data = ws.take_f32_cap(dy.data.len());
        sc_data.extend_from_slice(&dy.data);
        let d_sc = Buf { shape: dy.shape.clone(), data: sc_data };
        let mut d = self.bwd_matmul(ws, &t.conv2, dy, grads)?;
        kernels::relu_bwd(&t.relu2, &mut d.data);
        let d = bwd_bn(&t.bn2, d, grads)?;
        let mut d_pre = self.bwd_matmul(ws, &t.conv1, d, grads)?;
        match &t.proj {
            Some(proj) => {
                let d_proj = self.bwd_matmul(ws, proj, d_sc, grads)?;
                ensure!(d_proj.shape == d_pre.shape, "preact backward shape mismatch");
                for (a, b) in d_pre.data.iter_mut().zip(&d_proj.data) {
                    *a += b;
                }
                ws.recycle_f32(d_proj.data);
                kernels::relu_bwd(&t.relu1, &mut d_pre.data);
                bwd_bn(&t.bn1, d_pre, grads)
            }
            None => {
                kernels::relu_bwd(&t.relu1, &mut d_pre.data);
                let mut dx = bwd_bn(&t.bn1, d_pre, grads)?;
                ensure!(dx.shape == d_sc.shape, "preact backward shape mismatch");
                for (a, b) in dx.data.iter_mut().zip(&d_sc.data) {
                    *a += b;
                }
                ws.recycle_f32(d_sc.data);
                Ok(dx)
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Tape-local forward helpers (kernel calls + save-for-backward plumbing)
// ---------------------------------------------------------------------------

/// Copy a slice into a workspace buffer (pooled save-for-backward).
fn copy_into_ws(ws: &mut Workspace, x: &[f32]) -> Vec<f32> {
    let mut v = ws.take_f32_cap(x.len());
    v.extend_from_slice(x);
    v
}

fn fwd_relu(ws: &mut Workspace, mut act: Buf, train: bool) -> (Buf, Option<Vec<bool>>) {
    if train {
        let mut mask = ws.take_bool_cap(act.data.len());
        kernels::relu_mask(&mut act.data, &mut mask);
        (act, Some(mask))
    } else {
        kernels::relu(&mut act.data);
        (act, None)
    }
}

fn fwd_maxpool2(ws: &mut Workspace, act: &Buf, train: bool) -> Result<(Buf, Option<Vec<usize>>)> {
    let (b, h, w, c) = act.dims4()?;
    let (oh, ow) = (h / 2, w / 2);
    let mut out = ws.take_f32_any(b * oh * ow * c);
    let mut argmax = if train {
        Some(ws.take_usize_cap(b * oh * ow * c))
    } else {
        None
    };
    kernels::maxpool2(&act.data, b, h, w, c, &mut out, argmax.as_mut());
    Ok((Buf { shape: vec![b, oh, ow, c], data: out }, argmax))
}

fn fwd_gap(ws: &mut Workspace, act: &Buf) -> Result<Buf> {
    let (b, h, w, c) = act.dims4()?;
    let mut out = ws.take_f32_any(b * c);
    kernels::global_avg_pool(&act.data, b, h, w, c, &mut out);
    Ok(Buf { shape: vec![b, c], data: out })
}

/// Batch-norm backward ([`kernels::bn_bwd`]) + gradient-slot accumulation.
fn bwd_bn(t: &BnTape, mut dy: Buf, grads: &mut [Vec<f32>]) -> Result<Buf> {
    ensure!(dy.data.len() == t.xhat.len(), "bn backward shape");
    ensure!(dy.data.len() % t.ch.max(1) == 0, "bn backward channel layout");
    let (dgamma, dbeta) = kernels::bn_bwd(&t.xhat, &t.inv, &t.gamma, &mut dy.data);
    for (g, &d) in grads[t.gamma_gidx].iter_mut().zip(&dgamma) {
        *g += d as f32;
    }
    for (g, &d) in grads[t.beta_gidx].iter_mut().zip(&dbeta) {
        *g += d as f32;
    }
    Ok(dy)
}

/// Return every pooled tape buffer to the workspace once the backward walk
/// is done — the step's steady-state allocation story depends on this.
fn recycle_tape(ws: &mut Workspace, tape: Vec<Tape>) {
    for entry in tape {
        match entry {
            Tape::Matmul(t) => recycle_matmul(ws, t),
            Tape::Bn(t) => ws.recycle_f32(t.xhat),
            Tape::Relu(mask) => ws.recycle_bool(mask),
            Tape::MaxPool2 { argmax, .. } => ws.recycle_usize(argmax),
            Tape::Preact(p) => {
                let p = *p;
                ws.recycle_f32(p.bn1.xhat);
                ws.recycle_f32(p.bn2.xhat);
                ws.recycle_bool(p.relu1);
                ws.recycle_bool(p.relu2);
                if let Some(t) = p.proj {
                    recycle_matmul(ws, t);
                }
                recycle_matmul(ws, p.conv1);
                recycle_matmul(ws, p.conv2);
            }
            Tape::Gap { .. } | Tape::Flatten { .. } => {}
        }
    }
}

fn recycle_matmul(ws: &mut Workspace, t: MatmulTape) {
    ws.recycle_f32(t.cols);
    ws.recycle_f32(t.w_hat);
    if let Some(q) = t.aq {
        ws.recycle_f32(q.raw);
    }
    if let Some(q) = t.wq {
        ws.recycle_f32(q.raw);
    }
}
