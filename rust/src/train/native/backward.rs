//! Hand-written backward pass over the model-zoo architecture IR
//! ([`crate::runtime::native::arch`]) — the training datapath that lets
//! `cargo run -- train` reproduce the paper's central claim with no
//! XLA/PJRT.
//!
//! Training runs *fake quantization* in f32, exactly like the Python train
//! path (`python/compile/layers.py`): weights and input activations pass
//! through Eq. 1-2 elementwise, the matmul itself is fp32
//! ([`sgemm`]), and full-precision master weights receive the gradients
//! (Courbariaux et al. 2015). The backward is a tape walk:
//!
//! * matmul layers: `dŴ = X̂ᵀ·dY` ([`sgemm_tn`]), `dX̂ = dY·Ŵᵀ`
//!   ([`sgemm_nt`]), convolutions scatter `dX̂` back through the im2col
//!   adjoint ([`col2im`]);
//! * quantizers: the Eq. 5 STE mask gates `dX̂`/`dŴ` onto the raw inputs,
//!   and the Eq. 3 term (or a method-ablation variant, [`Method`])
//!   reduces to the step-size gradient, scaled by the Section-2.2
//!   `g = 1/√(N·Qp)` ([`gradscale_value`]) — N is the weight count for
//!   `sw` and the trailing feature count for `sa`, mirroring
//!   `layers._quantize_pair`;
//! * batch norm trains on batch statistics with the standard three-term
//!   backward and emits functional running-stat updates
//!   (momentum 0.9, eps 1e-5, as in `layers.batchnorm`).
//!
//! Every formula here is checked against central differences of the
//! STE-consistent surrogate in `tests/grad_check.rs` (see
//! [`super::grad::lsq_surrogate_f64`]).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, ensure, Result};

use crate::quant::lsq::{self, grad_v_mask, qrange};
use crate::runtime::native::arch::{self, Arch, ArchOp, BnSpec, ConvSpec, DenseSpec};
use crate::runtime::native::gemm::{col2im, im2col, sgemm, sgemm_nt, sgemm_tn};
use crate::runtime::{Family, Manifest};
use crate::tensor::{numel, Tensor};

use super::grad::{gradscale_value, softmax_xent, Method};

/// BN hyper-parameters, shared with `python/compile/layers.py`.
pub const BN_MOMENTUM: f32 = 0.9;
/// BN variance epsilon (matches `layers.BN_EPS`).
pub const BN_EPS: f32 = 1e-5;

// ---------------------------------------------------------------------------
// Activation buffer
// ---------------------------------------------------------------------------

struct Buf {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Buf {
    fn dims4(&self) -> Result<(usize, usize, usize, usize)> {
        match self.shape[..] {
            [b, h, w, c] => Ok((b, h, w, c)),
            _ => bail!("expected a 4-d NHWC activation, got shape {:?}", self.shape),
        }
    }

    fn dims2(&self) -> Result<(usize, usize)> {
        match self.shape[..] {
            [b, d] => Ok((b, d)),
            _ => bail!("expected a 2-d activation, got shape {:?}", self.shape),
        }
    }
}

// ---------------------------------------------------------------------------
// Tape
// ---------------------------------------------------------------------------

/// Saved context of one quantizer application (weights or activations).
struct QuantSave {
    /// Raw (pre-quantization) values, elementwise aligned with the
    /// gradient flowing back through the quantizer.
    raw: Vec<f32>,
    s: f32,
    qn: i64,
    qp: i64,
    gscale: f64,
    /// Gradient-slot index of the step-size parameter.
    g_idx: usize,
}

/// Conv-specific geometry needed by the im2col adjoint.
struct ConvGeom {
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    kh: usize,
    kw: usize,
    stride: usize,
}

/// One quantized (or fp32) matmul layer: conv (via im2col) or dense.
struct MatmulTape {
    m: usize,
    k: usize,
    n: usize,
    /// `X̂` in matmul layout (`m×k`): im2col output for conv, the
    /// (quantized) input matrix for dense.
    cols: Vec<f32>,
    /// `Ŵ` (`k×n`) as used in the forward.
    w_hat: Vec<f32>,
    w_gidx: usize,
    b_gidx: Option<usize>,
    /// Activation quantizer context (`None` for fp32 layers).
    aq: Option<QuantSave>,
    /// Weight quantizer context (`None` for fp32 layers).
    wq: Option<QuantSave>,
    /// Present for convolutions; `None` means dense (no col2im).
    conv: Option<ConvGeom>,
}

/// Batch-norm training context.
struct BnTape {
    ch: usize,
    rows: usize,
    /// Normalized activations `(x−μ)·inv`, pre-γβ.
    xhat: Vec<f32>,
    /// `1/√(var+eps)` per channel.
    inv: Vec<f32>,
    gamma: Vec<f32>,
    gamma_gidx: usize,
    beta_gidx: usize,
}

/// Pre-activation residual block: sub-tapes in forward order plus the
/// branch structure the backward must rejoin.
struct PreactTape {
    bn1: BnTape,
    relu1: Vec<bool>,
    proj: Option<MatmulTape>,
    conv1: MatmulTape,
    bn2: BnTape,
    relu2: Vec<bool>,
    conv2: MatmulTape,
}

enum Tape {
    Matmul(MatmulTape),
    Bn(BnTape),
    Relu(Vec<bool>),
    MaxPool2 { argmax: Vec<usize>, in_shape: [usize; 4] },
    Gap { in_shape: [usize; 4] },
    Flatten { in_shape: [usize; 4] },
    Preact(Box<PreactTape>),
}

/// One activation-quantizer statistic from a collect pass (Section 2.1):
/// the data-driven activation step init is `2·mean_abs/√qp`.
pub struct ActStat {
    /// Parameter name of the activation step (`{layer}.sa`).
    pub sa_name: String,
    /// Mean `|x|` over the layer's full (unquantized) input batch.
    pub mean_abs: f64,
    /// `Q_P` of the activation quantizer.
    pub qp: i64,
}

enum Pass<'a> {
    /// Training forward: record a tape + functional BN state updates.
    Train { tape: &'a mut Vec<Tape>, state_out: &'a mut Vec<(usize, Tensor)> },
    /// Inference forward: eval-mode BN, quantizers active, no tape.
    Eval,
    /// Section-2.1 collect pass: full-precision forward (no quantizers),
    /// batch-stat BN, record mean|x| at every activation quantizer.
    Collect { stats: &'a mut Vec<ActStat> },
}

impl Pass<'_> {
    fn is_train(&self) -> bool {
        matches!(self, Pass::Train { .. })
    }

    fn is_collect(&self) -> bool {
        matches!(self, Pass::Collect { .. })
    }
}

// ---------------------------------------------------------------------------
// Model
// ---------------------------------------------------------------------------

/// Output of one native train step's loss/gradient computation.
pub struct StepOutput {
    /// Mean softmax cross-entropy over the batch.
    pub loss: f64,
    /// Rows whose argmax logit equals the label.
    pub ncorrect: usize,
    /// Raw logits (`rows × num_classes`).
    pub logits: Vec<f32>,
    /// One gradient tensor per `Family::grad_names`, in order.
    pub grads: Vec<Tensor>,
    /// Functional BN running-stat updates as `(param index, new value)`.
    pub state_updates: Vec<(usize, Tensor)>,
}

/// A model family bound for *training*: the arch IR plus parameter/gradient
/// index maps. Unlike [`crate::runtime::native::NativeModel`] (which packs
/// weights once for serving), this holds no parameter state — every call
/// takes the current `params` so the optimizer owns the master copies.
pub struct NativeTrainModel {
    arch: Arch,
    family: String,
    method: Method,
    gscale_mode: String,
    pidx: BTreeMap<String, usize>,
    gidx: BTreeMap<String, usize>,
    grad_shapes: Vec<Vec<usize>>,
    image: usize,
    channels: usize,
    num_classes: usize,
}

impl NativeTrainModel {
    /// Bind `family`'s architecture for training under quantizer `method`
    /// and gradient-scale mode `gscale_mode` (both validated here).
    pub fn build(
        manifest: &Manifest,
        family: &str,
        method: &str,
        gscale_mode: &str,
    ) -> Result<NativeTrainModel> {
        let fam: &Family = manifest.family(family)?;
        let arch = arch::build(
            &fam.model,
            manifest.image,
            manifest.channels,
            fam.num_classes,
            fam.qbits,
        )?;
        // Resolve the method once (hot loops dispatch on the enum) and
        // fail fast on unknown gscale names.
        let method = Method::parse(method)?;
        gradscale_value(1, 1, gscale_mode)?;
        let pidx: BTreeMap<String, usize> =
            fam.param_names.iter().enumerate().map(|(i, n)| (n.clone(), i)).collect();
        let gidx: BTreeMap<String, usize> =
            fam.grad_names.iter().enumerate().map(|(i, n)| (n.clone(), i)).collect();
        let grad_shapes = fam
            .grad_names
            .iter()
            .map(|n| fam.shapes.get(n).cloned().unwrap_or_default())
            .collect();
        Ok(NativeTrainModel {
            arch,
            family: family.to_string(),
            method,
            gscale_mode: gscale_mode.to_string(),
            pidx,
            gidx,
            grad_shapes,
            image: manifest.image,
            channels: manifest.channels,
            num_classes: fam.num_classes,
        })
    }

    /// Per-image input element count.
    pub fn image_len(&self) -> usize {
        self.image * self.image * self.channels
    }

    /// Logit count per row.
    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    /// The family this model was built for.
    pub fn family(&self) -> &str {
        &self.family
    }

    fn param<'a>(&self, params: &'a [Tensor], name: &str) -> Result<&'a Tensor> {
        let i = *self
            .pidx
            .get(name)
            .ok_or_else(|| anyhow!("family {} has no parameter {name:?}", self.family))?;
        Ok(&params[i])
    }

    fn scalar(&self, params: &[Tensor], name: &str) -> Result<f32> {
        self.param(params, name)?.item_f32()
    }

    fn grad_slot(&self, name: &str) -> Result<usize> {
        self.gidx
            .get(name)
            .copied()
            .ok_or_else(|| anyhow!("parameter {name:?} has no gradient slot"))
    }

    fn check_input(&self, x: &[f32], rows: usize) -> Result<()> {
        ensure!(rows > 0, "empty batch");
        ensure!(
            x.len() == rows * self.image_len(),
            "input has {} floats, expected {} ({} rows x {})",
            x.len(),
            rows * self.image_len(),
            rows,
            self.image_len()
        );
        Ok(())
    }

    // -- forward ------------------------------------------------------------

    fn forward_pass(
        &self,
        params: &[Tensor],
        x: &[f32],
        rows: usize,
        pass: &mut Pass,
    ) -> Result<Buf> {
        self.check_input(x, rows)?;
        let mut act = Buf {
            shape: vec![rows, self.image, self.image, self.channels],
            data: x.to_vec(),
        };
        for op in &self.arch.ops {
            act = self.apply_op(params, act, op, pass)?;
        }
        ensure!(
            act.shape == [rows, self.num_classes],
            "forward produced shape {:?}, expected [{rows}, {}]",
            act.shape,
            self.num_classes
        );
        Ok(act)
    }

    fn apply_op(&self, params: &[Tensor], act: Buf, op: &ArchOp, pass: &mut Pass) -> Result<Buf> {
        Ok(match op {
            ArchOp::Conv(c) => {
                let (out, t) = self.fwd_conv(params, &act, c, pass)?;
                if let (Pass::Train { tape, .. }, Some(t)) = (&mut *pass, t) {
                    tape.push(Tape::Matmul(t));
                }
                out
            }
            ArchOp::Dense(d) => {
                let (out, t) = self.fwd_dense(params, &act, d, pass)?;
                if let (Pass::Train { tape, .. }, Some(t)) = (&mut *pass, t) {
                    tape.push(Tape::Matmul(t));
                }
                out
            }
            ArchOp::BatchNorm(b) => {
                let (out, t) = self.fwd_bn(params, act, b, pass)?;
                if let (Pass::Train { tape, .. }, Some(t)) = (&mut *pass, t) {
                    tape.push(Tape::Bn(t));
                }
                out
            }
            ArchOp::Relu => {
                let (out, mask) = fwd_relu(act, pass.is_train());
                if let (Pass::Train { tape, .. }, Some(m)) = (&mut *pass, mask) {
                    tape.push(Tape::Relu(m));
                }
                out
            }
            ArchOp::MaxPool2 => {
                let (b, h, w, c) = act.dims4()?;
                let (out, argmax) = fwd_maxpool2(&act, pass.is_train())?;
                if let (Pass::Train { tape, .. }, Some(a)) = (&mut *pass, argmax) {
                    tape.push(Tape::MaxPool2 { argmax: a, in_shape: [b, h, w, c] });
                }
                out
            }
            ArchOp::GlobalAvgPool => {
                let (b, h, w, c) = act.dims4()?;
                let out = fwd_gap(&act)?;
                if let Pass::Train { tape, .. } = pass {
                    tape.push(Tape::Gap { in_shape: [b, h, w, c] });
                }
                out
            }
            ArchOp::Flatten => {
                let (b, h, w, c) = act.dims4()?;
                if let Pass::Train { tape, .. } = pass {
                    tape.push(Tape::Flatten { in_shape: [b, h, w, c] });
                }
                Buf { shape: vec![b, h * w * c], data: act.data }
            }
            ArchOp::Preact(p) => {
                let (out, t) = self.fwd_preact(params, act, p, pass)?;
                if let (Pass::Train { tape, .. }, Some(t)) = (&mut *pass, t) {
                    tape.push(Tape::Preact(Box::new(t)));
                }
                out
            }
        })
    }

    /// Quantize one matmul operand pair for training, recording the
    /// quantizer contexts. Returns `(x_hat, w_hat, aq, wq)` — raw
    /// passthrough (and a collect stat) when `pass` is `Collect` or the
    /// layer is full precision.
    #[allow(clippy::type_complexity, clippy::too_many_arguments)]
    fn quantize_pair(
        &self,
        params: &[Tensor],
        name: &str,
        bits: u32,
        signed_act: bool,
        x: &[f32],
        w: &[f32],
        n_feat: usize,
        pass: &mut Pass,
    ) -> Result<(Vec<f32>, Vec<f32>, Option<QuantSave>, Option<QuantSave>)> {
        if bits >= 32 {
            return Ok((x.to_vec(), w.to_vec(), None, None));
        }
        let (aqn, aqp) = qrange(bits, signed_act);
        if let Pass::Collect { stats } = pass {
            let mean_abs = x.iter().map(|v| v.abs() as f64).sum::<f64>() / x.len().max(1) as f64;
            stats.push(ActStat { sa_name: format!("{name}.sa"), mean_abs, qp: aqp });
            return Ok((x.to_vec(), w.to_vec(), None, None));
        }
        let (wqn, wqp) = qrange(bits, true);
        let sw = self.scalar(params, &format!("{name}.sw"))?;
        let sa = self.scalar(params, &format!("{name}.sa"))?;
        ensure!(sw > 0.0 && sa > 0.0, "{name}: non-positive step size (sw={sw}, sa={sa})");
        let x_hat: Vec<f32> = x.iter().map(|&v| lsq::quantize(v, sa, aqn, aqp)).collect();
        let w_hat: Vec<f32> = w.iter().map(|&v| lsq::quantize(v, sw, wqn, wqp)).collect();
        let (aq, wq) = if pass.is_train() {
            let g_a = gradscale_value(n_feat, aqp, &self.gscale_mode)?;
            let g_w = gradscale_value(w.len(), wqp, &self.gscale_mode)?;
            (
                Some(QuantSave {
                    raw: x.to_vec(),
                    s: sa,
                    qn: aqn,
                    qp: aqp,
                    gscale: g_a,
                    g_idx: self.grad_slot(&format!("{name}.sa"))?,
                }),
                Some(QuantSave {
                    raw: w.to_vec(),
                    s: sw,
                    qn: wqn,
                    qp: wqp,
                    gscale: g_w,
                    g_idx: self.grad_slot(&format!("{name}.sw"))?,
                }),
            )
        } else {
            (None, None)
        };
        Ok((x_hat, w_hat, aq, wq))
    }

    fn fwd_conv(
        &self,
        params: &[Tensor],
        act: &Buf,
        spec: &ConvSpec,
        pass: &mut Pass,
    ) -> Result<(Buf, Option<MatmulTape>)> {
        let (b, h, w, c) = act.dims4()?;
        ensure!(c == spec.in_ch, "{}: input has {c} channels, expected {}", spec.name, spec.in_ch);
        let wt = self.param(params, &format!("{}.w", spec.name))?;
        ensure!(
            wt.shape == [spec.kh, spec.kw, spec.in_ch, spec.out_ch],
            "{}.w shape {:?}",
            spec.name,
            wt.shape
        );
        let (x_hat, w_hat, aq, wq) = self.quantize_pair(
            params,
            &spec.name,
            spec.bits,
            spec.signed_act,
            &act.data,
            wt.f32s()?,
            spec.in_ch,
            pass,
        )?;
        let k = spec.kh * spec.kw * c;
        let n = spec.out_ch;
        let mut cols: Vec<f32> = Vec::new();
        let (oh, ow) = im2col(&x_hat, 0.0, b, h, w, c, spec.kh, spec.kw, spec.stride, &mut cols);
        let m = b * oh * ow;
        let mut out = vec![0.0f32; m * n];
        sgemm(m, k, n, &cols, &w_hat, None, &mut out);
        let tape = if pass.is_train() {
            Some(MatmulTape {
                m,
                k,
                n,
                cols,
                w_hat,
                w_gidx: self.grad_slot(&format!("{}.w", spec.name))?,
                b_gidx: None,
                aq,
                wq,
                conv: Some(ConvGeom { b, h, w, c, kh: spec.kh, kw: spec.kw, stride: spec.stride }),
            })
        } else {
            None
        };
        Ok((Buf { shape: vec![b, oh, ow, n], data: out }, tape))
    }

    fn fwd_dense(
        &self,
        params: &[Tensor],
        act: &Buf,
        spec: &DenseSpec,
        pass: &mut Pass,
    ) -> Result<(Buf, Option<MatmulTape>)> {
        let (m, d) = act.dims2()?;
        ensure!(d == spec.in_dim, "{}: input dim {d} != expected {}", spec.name, spec.in_dim);
        let wt = self.param(params, &format!("{}.w", spec.name))?;
        ensure!(
            wt.shape == [spec.in_dim, spec.out_dim],
            "{}.w shape {:?}",
            spec.name,
            wt.shape
        );
        let (x_hat, w_hat, aq, wq) = self.quantize_pair(
            params,
            &spec.name,
            spec.bits,
            spec.signed_act,
            &act.data,
            wt.f32s()?,
            spec.in_dim,
            pass,
        )?;
        let n = spec.out_dim;
        let bias_name = format!("{}.b", spec.name);
        let bias = match self.pidx.get(&bias_name) {
            Some(&i) => {
                ensure!(params[i].numel() == n, "{bias_name} wrong length");
                Some(params[i].f32s()?.to_vec())
            }
            None => None,
        };
        let mut out = vec![0.0f32; m * n];
        sgemm(m, d, n, &x_hat, &w_hat, bias.as_deref(), &mut out);
        let tape = if pass.is_train() {
            Some(MatmulTape {
                m,
                k: d,
                n,
                cols: x_hat,
                w_hat,
                w_gidx: self.grad_slot(&format!("{}.w", spec.name))?,
                b_gidx: bias.as_ref().map(|_| self.grad_slot(&bias_name)).transpose()?,
                aq,
                wq,
                conv: None,
            })
        } else {
            None
        };
        Ok((Buf { shape: vec![m, n], data: out }, tape))
    }

    fn fwd_bn(
        &self,
        params: &[Tensor],
        mut act: Buf,
        spec: &BnSpec,
        pass: &mut Pass,
    ) -> Result<(Buf, Option<BnTape>)> {
        let ch = *act.shape.last().unwrap_or(&0);
        ensure!(ch == spec.ch, "{}: {ch} channels, expected {}", spec.name, spec.ch);
        let gamma = self.param(params, &format!("{}.gamma", spec.name))?.f32s()?.to_vec();
        let beta = self.param(params, &format!("{}.beta", spec.name))?.f32s()?;
        let rows = act.data.len() / ch;
        ensure!(rows > 0, "{}: empty input", spec.name);

        let (mean, var) = if pass.is_train() || pass.is_collect() {
            // Batch statistics (biased variance, like jnp.var).
            let mut mean = vec![0.0f64; ch];
            let mut var = vec![0.0f64; ch];
            for chunk in act.data.chunks_exact(ch) {
                for (i, &v) in chunk.iter().enumerate() {
                    mean[i] += v as f64;
                }
            }
            for m in mean.iter_mut() {
                *m /= rows as f64;
            }
            for chunk in act.data.chunks_exact(ch) {
                for (i, &v) in chunk.iter().enumerate() {
                    let d = v as f64 - mean[i];
                    var[i] += d * d;
                }
            }
            for v in var.iter_mut() {
                *v /= rows as f64;
            }
            (
                mean.iter().map(|&v| v as f32).collect::<Vec<f32>>(),
                var.iter().map(|&v| v as f32).collect::<Vec<f32>>(),
            )
        } else {
            (
                self.param(params, &format!("{}.rmean", spec.name))?.f32s()?.to_vec(),
                self.param(params, &format!("{}.rvar", spec.name))?.f32s()?.to_vec(),
            )
        };

        let train = pass.is_train();
        let inv: Vec<f32> = var.iter().map(|&v| 1.0 / (v + BN_EPS).sqrt()).collect();
        let mut xhat = if train {
            Vec::with_capacity(act.data.len())
        } else {
            Vec::new()
        };
        for chunk in act.data.chunks_exact_mut(ch) {
            for (i, v) in chunk.iter_mut().enumerate() {
                let nx = (*v - mean[i]) * inv[i];
                if train {
                    xhat.push(nx);
                }
                *v = nx * gamma[i] + beta[i];
            }
        }

        let tape = if let Pass::Train { state_out, .. } = pass {
            // Functional running-stat updates (mirrors layers.batchnorm).
            let rmean_name = format!("{}.rmean", spec.name);
            let rvar_name = format!("{}.rvar", spec.name);
            let rmean = self.param(params, &rmean_name)?.f32s()?;
            let rvar = self.param(params, &rvar_name)?.f32s()?;
            let new_rmean: Vec<f32> = rmean
                .iter()
                .zip(&mean)
                .map(|(&r, &m)| BN_MOMENTUM * r + (1.0 - BN_MOMENTUM) * m)
                .collect();
            let new_rvar: Vec<f32> = rvar
                .iter()
                .zip(&var)
                .map(|(&r, &v)| BN_MOMENTUM * r + (1.0 - BN_MOMENTUM) * v)
                .collect();
            let rmean_idx = *self
                .pidx
                .get(&rmean_name)
                .ok_or_else(|| anyhow!("no param {rmean_name}"))?;
            let rvar_idx = *self
                .pidx
                .get(&rvar_name)
                .ok_or_else(|| anyhow!("no param {rvar_name}"))?;
            state_out.push((rmean_idx, Tensor::from_f32(&[ch], new_rmean)));
            state_out.push((rvar_idx, Tensor::from_f32(&[ch], new_rvar)));
            Some(BnTape {
                ch,
                rows,
                xhat,
                inv,
                gamma,
                gamma_gidx: self.grad_slot(&format!("{}.gamma", spec.name))?,
                beta_gidx: self.grad_slot(&format!("{}.beta", spec.name))?,
            })
        } else {
            None
        };
        Ok((act, tape))
    }

    fn fwd_preact(
        &self,
        params: &[Tensor],
        x: Buf,
        p: &arch::PreactSpec,
        pass: &mut Pass,
    ) -> Result<(Buf, Option<PreactTape>)> {
        // pre = relu(bn1(x)); shortcut from `pre` when projecting, raw x
        // otherwise (mirrors runtime::native::apply_preact).
        let x_copy = if p.proj.is_none() {
            Some(Buf { shape: x.shape.clone(), data: x.data.clone() })
        } else {
            None
        };
        let (pre, bn1_t) = self.fwd_bn(params, x, &p.bn1, pass)?;
        let (pre, relu1_m) = fwd_relu(pre, pass.is_train());
        let (sc, proj_t) = match &p.proj {
            Some(proj) => {
                let (sc, t) = self.fwd_conv(params, &pre, proj, pass)?;
                (sc, t)
            }
            None => (x_copy.unwrap(), None),
        };
        let (h, conv1_t) = self.fwd_conv(params, &pre, &p.conv1, pass)?;
        let (h, bn2_t) = self.fwd_bn(params, h, &p.bn2, pass)?;
        let (h, relu2_m) = fwd_relu(h, pass.is_train());
        let (mut h, conv2_t) = self.fwd_conv(params, &h, &p.conv2, pass)?;
        ensure!(h.shape == sc.shape, "residual shape mismatch: {:?} vs {:?}", h.shape, sc.shape);
        for (a, b) in h.data.iter_mut().zip(&sc.data) {
            *a += b;
        }
        let tape = if pass.is_train() {
            Some(PreactTape {
                bn1: bn1_t.unwrap(),
                relu1: relu1_m.unwrap(),
                proj: proj_t,
                conv1: conv1_t.unwrap(),
                bn2: bn2_t.unwrap(),
                relu2: relu2_m.unwrap(),
                conv2: conv2_t.unwrap(),
            })
        } else {
            None
        };
        Ok((h, tape))
    }

    // -- public entry points -------------------------------------------------

    /// Inference forward (eval-mode BN, quantizers active): returns
    /// `rows × num_classes` logits.
    pub fn forward_eval(&self, params: &[Tensor], x: &[f32], rows: usize) -> Result<Vec<f32>> {
        Ok(self.forward_pass(params, x, rows, &mut Pass::Eval)?.data)
    }

    /// Section-2.1 collect pass over one (unaugmented) batch: runs the
    /// *unquantized* network and records mean|x| at every activation
    /// quantizer, for the `2⟨|v|⟩/√Qp` activation-step init.
    pub fn collect_act_stats(
        &self,
        params: &[Tensor],
        x: &[f32],
        rows: usize,
    ) -> Result<Vec<ActStat>> {
        let mut stats = Vec::new();
        self.forward_pass(params, x, rows, &mut Pass::Collect { stats: &mut stats })?;
        Ok(stats)
    }

    /// One training forward+backward on a batch: softmax cross-entropy
    /// loss, gradients for every `Family::grad_names` slot, and the
    /// functional BN state updates.
    pub fn loss_and_grads(
        &self,
        params: &[Tensor],
        x: &[f32],
        y: &[i32],
        rows: usize,
    ) -> Result<StepOutput> {
        ensure!(y.len() >= rows, "labels shorter than batch");
        let mut tape: Vec<Tape> = Vec::new();
        let mut state_out: Vec<(usize, Tensor)> = Vec::new();
        let logits = self.forward_pass(
            params,
            x,
            rows,
            &mut Pass::Train { tape: &mut tape, state_out: &mut state_out },
        )?;
        let (loss, ncorrect, dlogits) = softmax_xent(&logits.data, y, self.num_classes, rows);
        let mut grads: Vec<Vec<f32>> =
            self.grad_shapes.iter().map(|s| vec![0.0f32; numel(s)]).collect();
        let mut d = Buf { shape: vec![rows, self.num_classes], data: dlogits };
        for entry in tape.iter().rev() {
            d = self.bwd_op(entry, d, &mut grads)?;
        }
        let grads = grads
            .into_iter()
            .zip(&self.grad_shapes)
            .map(|(g, s)| Tensor::from_f32(s, g))
            .collect();
        Ok(StepOutput { loss, ncorrect, logits: logits.data, grads, state_updates: state_out })
    }

    // -- backward ------------------------------------------------------------

    fn bwd_op(&self, entry: &Tape, dy: Buf, grads: &mut [Vec<f32>]) -> Result<Buf> {
        Ok(match entry {
            Tape::Matmul(t) => self.bwd_matmul(t, dy, grads)?,
            Tape::Bn(t) => bwd_bn(t, dy, grads)?,
            Tape::Relu(mask) => bwd_relu(mask, dy),
            Tape::MaxPool2 { argmax, in_shape } => bwd_maxpool2(argmax, in_shape, dy)?,
            Tape::Gap { in_shape } => bwd_gap(in_shape, dy)?,
            Tape::Flatten { in_shape } => {
                Buf { shape: in_shape.to_vec(), data: dy.data }
            }
            Tape::Preact(t) => self.bwd_preact(t, dy, grads)?,
        })
    }

    fn bwd_matmul(&self, t: &MatmulTape, dy: Buf, grads: &mut [Vec<f32>]) -> Result<Buf> {
        let (m, k, n) = (t.m, t.k, t.n);
        ensure!(dy.data.len() == m * n, "matmul backward: dY has wrong shape");

        // dŴ = X̂ᵀ · dY, then through the weight quantizer (Eq. 5 mask on
        // the raw weights, Eq. 3 reduction to dsw).
        let mut dw_hat = vec![0.0f32; k * n];
        sgemm_tn(m, k, n, &t.cols, &dy.data, &mut dw_hat);
        match &t.wq {
            Some(q) => {
                let mut ds = 0.0f64;
                let gw = &mut grads[t.w_gidx];
                for (i, &d) in dw_hat.iter().enumerate() {
                    let v = q.raw[i];
                    gw[i] += d * grad_v_mask(v, q.s, q.qn, q.qp);
                    ds += d as f64 * self.method.ds_term(v, q.s, q.qn, q.qp) as f64;
                }
                grads[q.g_idx][0] += (ds * q.gscale) as f32;
            }
            None => {
                let gw = &mut grads[t.w_gidx];
                for (g, &d) in gw.iter_mut().zip(&dw_hat) {
                    *g += d;
                }
            }
        }

        // db = column sums of dY.
        if let Some(bg) = t.b_gidx {
            let gb = &mut grads[bg];
            for i in 0..m {
                let row = &dy.data[i * n..(i + 1) * n];
                for (g, &d) in gb.iter_mut().zip(row) {
                    *g += d;
                }
            }
        }

        // dX̂ = dY · Ŵᵀ; convolutions scatter back through the im2col
        // adjoint so each input element accumulates over every patch that
        // read it.
        let mut dcols = vec![0.0f32; m * k];
        sgemm_nt(m, k, n, &dy.data, &t.w_hat, &mut dcols);
        let (mut dxhat, in_shape): (Vec<f32>, Vec<usize>) = match &t.conv {
            Some(g) => {
                let mut dx = vec![0.0f32; g.b * g.h * g.w * g.c];
                col2im(&dcols, g.b, g.h, g.w, g.c, g.kh, g.kw, g.stride, &mut dx);
                (dx, vec![g.b, g.h, g.w, g.c])
            }
            None => (dcols, vec![m, k]),
        };

        // Through the activation quantizer: dsa reduces over the *input*
        // elements (post-col2im), then the STE mask gates dX.
        if let Some(q) = &t.aq {
            let mut ds = 0.0f64;
            for (i, d) in dxhat.iter_mut().enumerate() {
                let v = q.raw[i];
                ds += *d as f64 * self.method.ds_term(v, q.s, q.qn, q.qp) as f64;
                *d *= grad_v_mask(v, q.s, q.qn, q.qp);
            }
            grads[q.g_idx][0] += (ds * q.gscale) as f32;
        }
        Ok(Buf { shape: in_shape, data: dxhat })
    }

    fn bwd_preact(&self, t: &PreactTape, dy: Buf, grads: &mut [Vec<f32>]) -> Result<Buf> {
        // Residual: dout feeds both the conv branch and the shortcut.
        let d_sc = Buf { shape: dy.shape.clone(), data: dy.data.clone() };
        let d = self.bwd_matmul(&t.conv2, dy, grads)?;
        let d = bwd_relu(&t.relu2, d);
        let d = bwd_bn(&t.bn2, d, grads)?;
        let mut d_pre = self.bwd_matmul(&t.conv1, d, grads)?;
        match &t.proj {
            Some(proj) => {
                let d_proj = self.bwd_matmul(proj, d_sc, grads)?;
                ensure!(d_proj.shape == d_pre.shape, "preact backward shape mismatch");
                for (a, b) in d_pre.data.iter_mut().zip(&d_proj.data) {
                    *a += b;
                }
                let d = bwd_relu(&t.relu1, d_pre);
                bwd_bn(&t.bn1, d, grads)
            }
            None => {
                let d = bwd_relu(&t.relu1, d_pre);
                let mut dx = bwd_bn(&t.bn1, d, grads)?;
                ensure!(dx.shape == d_sc.shape, "preact backward shape mismatch");
                for (a, b) in dx.data.iter_mut().zip(&d_sc.data) {
                    *a += b;
                }
                Ok(dx)
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Elementwise / pooling ops (free functions: no parameters involved)
// ---------------------------------------------------------------------------

fn fwd_relu(mut act: Buf, train: bool) -> (Buf, Option<Vec<bool>>) {
    let mask = if train {
        Some(act.data.iter().map(|&v| v > 0.0).collect())
    } else {
        None
    };
    for v in &mut act.data {
        *v = v.max(0.0);
    }
    (act, mask)
}

fn bwd_relu(mask: &[bool], mut dy: Buf) -> Buf {
    for (d, &m) in dy.data.iter_mut().zip(mask) {
        if !m {
            *d = 0.0;
        }
    }
    dy
}

fn fwd_maxpool2(act: &Buf, train: bool) -> Result<(Buf, Option<Vec<usize>>)> {
    let (b, h, w, c) = act.dims4()?;
    let (oh, ow) = (h / 2, w / 2);
    let mut out = vec![f32::NEG_INFINITY; b * oh * ow * c];
    let mut arg = vec![0usize; if train { b * oh * ow * c } else { 0 }];
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let dst = ((bi * oh + oy) * ow + ox) * c;
                for dy_ in 0..2 {
                    for dx_ in 0..2 {
                        let src = ((bi * h + oy * 2 + dy_) * w + ox * 2 + dx_) * c;
                        for ch in 0..c {
                            let v = act.data[src + ch];
                            if v > out[dst + ch] {
                                out[dst + ch] = v;
                                if train {
                                    arg[dst + ch] = src + ch;
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    let argmax = if train { Some(arg) } else { None };
    Ok((Buf { shape: vec![b, oh, ow, c], data: out }, argmax))
}

fn bwd_maxpool2(argmax: &[usize], in_shape: &[usize; 4], dy: Buf) -> Result<Buf> {
    ensure!(dy.data.len() == argmax.len(), "maxpool backward shape");
    let mut dx = vec![0.0f32; in_shape.iter().product()];
    for (&a, &d) in argmax.iter().zip(&dy.data) {
        dx[a] += d;
    }
    Ok(Buf { shape: in_shape.to_vec(), data: dx })
}

fn fwd_gap(act: &Buf) -> Result<Buf> {
    let (b, h, w, c) = act.dims4()?;
    let inv = 1.0 / (h * w) as f32;
    let mut out = vec![0.0f32; b * c];
    for bi in 0..b {
        for p in 0..h * w {
            let src = (bi * h * w + p) * c;
            for ch in 0..c {
                out[bi * c + ch] += act.data[src + ch];
            }
        }
        for ch in 0..c {
            out[bi * c + ch] *= inv;
        }
    }
    Ok(Buf { shape: vec![b, c], data: out })
}

fn bwd_gap(in_shape: &[usize; 4], dy: Buf) -> Result<Buf> {
    let [b, h, w, c] = *in_shape;
    ensure!(dy.data.len() == b * c, "gap backward shape");
    let inv = 1.0 / (h * w) as f32;
    let mut dx = vec![0.0f32; b * h * w * c];
    for bi in 0..b {
        for p in 0..h * w {
            let dst = (bi * h * w + p) * c;
            for ch in 0..c {
                dx[dst + ch] = dy.data[bi * c + ch] * inv;
            }
        }
    }
    Ok(Buf { shape: in_shape.to_vec(), data: dx })
}

/// Standard three-term batch-norm backward over the saved normalized
/// activations: `dx = inv/N · (N·dx̂ − Σdx̂ − x̂·Σ(dx̂·x̂))` per channel,
/// plus `dγ = Σ dy·x̂` and `dβ = Σ dy`.
fn bwd_bn(t: &BnTape, mut dy: Buf, grads: &mut [Vec<f32>]) -> Result<Buf> {
    let ch = t.ch;
    let rows = t.rows;
    ensure!(dy.data.len() == rows * ch, "bn backward shape");
    let mut dgamma = vec![0.0f64; ch];
    let mut dbeta = vec![0.0f64; ch];
    let mut s1 = vec![0.0f64; ch];
    let mut s2 = vec![0.0f64; ch];
    for (r, chunk) in dy.data.chunks_exact_mut(ch).enumerate() {
        let xh = &t.xhat[r * ch..(r + 1) * ch];
        for i in 0..ch {
            let g = chunk[i] as f64;
            dgamma[i] += g * xh[i] as f64;
            dbeta[i] += g;
            let dxh = g * t.gamma[i] as f64;
            s1[i] += dxh;
            s2[i] += dxh * xh[i] as f64;
            chunk[i] = dxh as f32; // dy buffer now holds dx̂
        }
    }
    let n = rows as f64;
    for (r, chunk) in dy.data.chunks_exact_mut(ch).enumerate() {
        let xh = &t.xhat[r * ch..(r + 1) * ch];
        for i in 0..ch {
            let dxh = chunk[i] as f64;
            chunk[i] = (t.inv[i] as f64 * (dxh - s1[i] / n - xh[i] as f64 * s2[i] / n)) as f32;
        }
    }
    for (g, &d) in grads[t.gamma_gidx].iter_mut().zip(&dgamma) {
        *g += d as f32;
    }
    for (g, &d) in grads[t.beta_gidx].iter_mut().zip(&dbeta) {
        *g += d as f32;
    }
    Ok(dy)
}
