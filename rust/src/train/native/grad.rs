//! Training-side quantizer gradients and the finite-difference harness.
//!
//! The elementwise forward (Eqs. 1-2) and the LSQ backward terms (Eq. 5
//! STE mask, Eq. 3 step gradient) live in [`crate::quant::lsq`]; this
//! module adds what only the *training* path needs:
//!
//! * the competing step-size gradient estimators (`qil`, `pact`, `fixed`)
//!   so the native trainer covers the paper's method ablation exactly like
//!   `python/compile/quantizers.py`;
//! * the Section-2.2 gradient-scale modes (`full`, `sqrtn`, `one`, `x10`,
//!   `d10` — the Table-3 ablation knob);
//! * softmax cross-entropy with its gradient (the loss head of the native
//!   backward pass);
//! * the grad-check harness: an f64 *surrogate* of the STE-quantizer that
//!   is genuinely differentiable — `h(v, s) = s·(clip(v/s) + c)` with the
//!   rounding offset `c = round(r₀) − clip(r₀)` frozen at the evaluation
//!   point — whose exact derivatives are the Eq. 5 / Eq. 3 formulas. The
//!   hand-written backward is checked against central differences of this
//!   surrogate (`tests/grad_check.rs`), which catches sign errors, missing
//!   `−r` terms, wrong clip boundaries and gscale plumbing, while staying
//!   well-defined where the raw round() is piecewise constant.

use anyhow::{bail, Result};

use crate::quant::lsq::{grad_s_term, grad_scale};

/// Step-size gradient estimator, resolved once at model-build time so the
/// per-element backward loops dispatch on a copyable enum instead of a
/// string (mirrors the method set of `python/compile/quantizers.py`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Eq. 3: the paper's estimator (`lsq_jnp` is the same math on the
    /// Python side, kept as a separate name for artifact bookkeeping).
    Lsq,
    /// Jung et al. 2019: linear inside the domain, blind to transitions.
    Qil,
    /// Choi et al. 2018: non-zero only past the clip points.
    Pact,
    /// Static fit: no gradient to s at all.
    Fixed,
}

impl Method {
    /// Parse a config method name (`lsq`, `lsq_jnp`, `qil`, `pact`,
    /// `fixed`).
    pub fn parse(name: &str) -> Result<Method> {
        Ok(match name {
            "lsq" | "lsq_jnp" => Method::Lsq,
            "qil" => Method::Qil,
            "pact" => Method::Pact,
            "fixed" => Method::Fixed,
            other => bail!("unknown quantizer method {other:?}"),
        })
    }

    /// Per-element d(v̂)/d(s): all methods share the Eq. 1-2 forward and
    /// the Eq. 5 data gradient, differing only in this term.
    #[inline]
    pub fn ds_term(self, v: f32, s: f32, qn: i64, qp: i64) -> f32 {
        let r = v / s;
        match self {
            Method::Lsq => grad_s_term(v, s, qn, qp),
            Method::Qil => r.clamp(-(qn as f32), qp as f32),
            Method::Pact => {
                if r >= qp as f32 {
                    qp as f32
                } else if r <= -(qn as f32) {
                    -(qn as f32)
                } else {
                    0.0
                }
            }
            Method::Fixed => 0.0,
        }
    }
}

/// Per-element d(v̂)/d(s) for quantizer method `method` (string form;
/// resolves through [`Method::parse`] — hot loops should resolve once and
/// call [`Method::ds_term`] directly).
pub fn ds_term(method: &str, v: f32, s: f32, qn: i64, qp: i64) -> Result<f32> {
    Ok(Method::parse(method)?.ds_term(v, s, qn, qp))
}

/// The Section-2.2 gradient scale g for a quantizer over `n_items`
/// elements, per `gscale_mode` (Table-3 ablation knob):
/// `full` = 1/√(N·Qp) (via [`grad_scale`] — single source of the paper's
/// formula), `sqrtn` = 1/√N, `one` = 1, `x10`/`d10` = full scaled by 10 /
/// by 1/10.
pub fn gradscale_value(n_items: usize, qp: i64, mode: &str) -> Result<f64> {
    let n = n_items.max(1);
    Ok(match mode {
        "one" => 1.0,
        "sqrtn" => 1.0 / (n as f64).sqrt(),
        "full" => grad_scale(n, qp),
        "x10" => 10.0 * grad_scale(n, qp),
        "d10" => 0.1 * grad_scale(n, qp),
        other => bail!("unknown gscale mode {other:?}"),
    })
}

/// Per-row softmax statistics: `(maxv, denom, logz, argmax)`.
///
/// NaN-tolerant on purpose (like `metrics::topk_correct`): a diverged run
/// must surface as a NaN loss in its job report, not panic the sweep
/// worker.
fn softmax_row(row: &[f32]) -> (f32, f64, f64, usize) {
    let mut maxv = f32::NEG_INFINITY;
    let mut argmax = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > maxv {
            maxv = v;
            argmax = i;
        }
    }
    let mut denom = 0.0f64;
    for &v in row {
        denom += ((v - maxv) as f64).exp();
    }
    (maxv, denom, denom.ln() + maxv as f64, argmax)
}

/// Mean softmax cross-entropy + argmax-correct count, with no gradient
/// buffer — the eval path's variant of [`softmax_xent`].
pub fn softmax_xent_loss(
    logits: &[f32],
    labels: &[i32],
    classes: usize,
    rows: usize,
) -> (f64, usize) {
    assert_eq!(logits.len(), rows * classes, "logits shape");
    assert!(labels.len() >= rows, "labels shape");
    let mut loss = 0.0f64;
    let mut ncorrect = 0usize;
    for r in 0..rows {
        let row = &logits[r * classes..(r + 1) * classes];
        let target = labels[r] as usize;
        let (_, _, logz, argmax) = softmax_row(row);
        loss += logz - row[target] as f64;
        if argmax == target {
            ncorrect += 1;
        }
    }
    (loss / rows as f64, ncorrect)
}

/// Mean softmax cross-entropy over `rows` logit rows, plus its gradient
/// and the argmax-correct count — the loss head the native train step
/// shares with `python/compile/train.py` (`cross_entropy` + `_n_correct`).
///
/// Returns `(loss, ncorrect, dlogits)` with `dlogits[r, c] =
/// (softmax(logits)[r, c] − 1[c == y_r]) / rows`.
pub fn softmax_xent(
    logits: &[f32],
    labels: &[i32],
    classes: usize,
    rows: usize,
) -> (f64, usize, Vec<f32>) {
    assert_eq!(logits.len(), rows * classes, "logits shape");
    assert!(labels.len() >= rows, "labels shape");
    let mut dlogits = vec![0.0f32; rows * classes];
    let mut loss = 0.0f64;
    let mut ncorrect = 0usize;
    let inv_rows = 1.0f32 / rows as f32;
    for r in 0..rows {
        let row = &logits[r * classes..(r + 1) * classes];
        let target = labels[r] as usize;
        let (maxv, denom, logz, argmax) = softmax_row(row);
        loss += logz - row[target] as f64;
        if argmax == target {
            ncorrect += 1;
        }
        let drow = &mut dlogits[r * classes..(r + 1) * classes];
        for (c, d) in drow.iter_mut().enumerate() {
            let p = (((row[c] - maxv) as f64).exp() / denom) as f32;
            *d = (p - if c == target { 1.0 } else { 0.0 }) * inv_rows;
        }
    }
    (loss / rows as f64, ncorrect, dlogits)
}

// ---------------------------------------------------------------------------
// Grad-check harness
// ---------------------------------------------------------------------------

/// The STE-consistent f64 surrogate of the LSQ quantizer, with the
/// rounding offset frozen at `(v0, s0)`:
/// `h(v, s) = s · (clip(v/s, −Qn, Qp) + c)`, `c = round(clip(v0/s0)) −
/// clip(v0/s0)`.
///
/// At `(v0, s0)` the surrogate equals the real quantizer output, and its
/// exact partial derivatives are the hand-written backward formulas — the
/// Eq. 5 mask in `v` and the Eq. 3 term in `s` — so central differences of
/// `h` are a legitimate reference for the custom VJP wherever the frozen
/// offset stays valid (see [`safe_gradcheck_point`]).
pub fn lsq_surrogate_f64(v: f64, s: f64, v0: f64, s0: f64, qn: i64, qp: i64) -> f64 {
    let clip0 = (v0 / s0).clamp(-(qn as f64), qp as f64);
    let c = clip0.round_ties_even_compat() - clip0;
    let clip = (v / s).clamp(-(qn as f64), qp as f64);
    s * (clip + c)
}

/// Round-half-to-even for f64 without relying on a recent std method
/// (keeps the grad-check harness buildable on older toolchains).
trait RoundTiesEvenCompat {
    fn round_ties_even_compat(self) -> f64;
}

impl RoundTiesEvenCompat for f64 {
    fn round_ties_even_compat(self) -> f64 {
        let f = self.floor();
        let diff = self - f;
        if diff > 0.5 {
            f + 1.0
        } else if diff < 0.5 {
            f
        } else if (f as i64) % 2 == 0 {
            f
        } else {
            f + 1.0
        }
    }
}

/// Fourth-order central difference `df/dx` at `x` with step `h`.
pub fn central_diff(f: impl Fn(f64) -> f64, x: f64, h: f64) -> f64 {
    (8.0 * (f(x + h) - f(x - h)) - (f(x + 2.0 * h) - f(x - 2.0 * h))) / (12.0 * h)
}

/// `true` when `(v, s)` is a safe point for finite-differencing the
/// surrogate: `v/s` stays at least `margin` away from the clip boundaries
/// and from the nearest rounding tie, so neither the STE mask nor the
/// frozen offset changes within the stencil.
pub fn safe_gradcheck_point(v: f64, s: f64, qn: i64, qp: i64, margin: f64) -> bool {
    let r = v / s;
    let tie_dist = (r - r.floor() - 0.5).abs();
    let lo = -(qn as f64);
    let hi = qp as f64;
    tie_dist > margin && (r - lo).abs() > margin && (r - hi).abs() > margin
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ds_term_variants_match_reference_shapes() {
        let (qn, qp) = (2i64, 1i64);
        // inside the domain: lsq is the sawtooth, qil is linear, pact zero
        assert!((ds_term("lsq", 0.3, 1.0, qn, qp).unwrap() - (0.0 - 0.3)).abs() < 1e-6);
        assert!((ds_term("qil", 0.3, 1.0, qn, qp).unwrap() - 0.3).abs() < 1e-6);
        assert_eq!(ds_term("pact", 0.3, 1.0, qn, qp).unwrap(), 0.0);
        assert_eq!(ds_term("fixed", 0.3, 1.0, qn, qp).unwrap(), 0.0);
        // saturated: lsq, qil and pact all clamp to the clip level
        for m in ["lsq", "qil", "pact"] {
            assert_eq!(ds_term(m, 100.0, 1.0, qn, qp).unwrap(), qp as f32, "{m}");
            assert_eq!(ds_term(m, -100.0, 1.0, qn, qp).unwrap(), -(qn as f32), "{m}");
        }
        assert!(ds_term("nope", 0.0, 1.0, qn, qp).is_err());
    }

    #[test]
    fn gradscale_modes_match_python() {
        let n = 1000usize;
        let qp = 7i64;
        let full = gradscale_value(n, qp, "full").unwrap();
        assert!((full - 1.0 / (7000.0f64).sqrt()).abs() < 1e-12);
        let sqrtn = gradscale_value(n, qp, "sqrtn").unwrap();
        assert!((sqrtn - 1.0 / (1000.0f64).sqrt()).abs() < 1e-12);
        assert_eq!(gradscale_value(n, qp, "one").unwrap(), 1.0);
        assert!((gradscale_value(n, qp, "x10").unwrap() - 10.0 * full).abs() < 1e-12);
        assert!((gradscale_value(n, qp, "d10").unwrap() - 0.1 * full).abs() < 1e-12);
        assert!(gradscale_value(n, qp, "nope").is_err());
    }

    #[test]
    fn softmax_xent_uniform_logits() {
        // All-zero logits: loss = ln(C), gradient = (1/C - onehot)/rows.
        let classes = 4usize;
        let rows = 2usize;
        let logits = vec![0.0f32; rows * classes];
        let labels = vec![1i32, 3];
        let (loss, _nc, d) = softmax_xent(&logits, &labels, classes, rows);
        assert!((loss - (classes as f64).ln()).abs() < 1e-6);
        assert!((d[0] - 0.25 / 2.0).abs() < 1e-6);
        assert!((d[1] - (0.25 - 1.0) / 2.0).abs() < 1e-6);
        // gradient rows sum to zero
        let s: f32 = d.iter().sum();
        assert!(s.abs() < 1e-6);
    }

    #[test]
    fn softmax_xent_counts_argmax() {
        let logits = vec![2.0f32, 0.0, 0.0, 5.0]; // 2 rows x 2 classes
        let (_, nc, _) = softmax_xent(&logits, &[0, 0], 2, 2);
        assert_eq!(nc, 1);
    }

    #[test]
    fn surrogate_equals_quantizer_at_center() {
        use crate::quant::lsq::{qrange, quantize};
        for bits in [2u32, 3, 4, 8] {
            for signed in [true, false] {
                let (qn, qp) = qrange(bits, signed);
                for &(v, s) in &[(0.37f64, 0.21f64), (-0.83, 0.4), (9.0, 0.05)] {
                    let h = lsq_surrogate_f64(v, s, v, s, qn, qp);
                    let q = quantize(v as f32, s as f32, qn, qp) as f64;
                    assert!((h - q).abs() < 1e-5, "bits={bits} v={v} s={s}: {h} vs {q}");
                }
            }
        }
    }

    #[test]
    fn central_diff_is_fourth_order() {
        let d = central_diff(|x| x * x * x, 2.0, 1e-3);
        assert!((d - 12.0).abs() < 1e-8, "{d}");
    }

    #[test]
    fn safe_points_exclude_ties_and_clips() {
        assert!(!safe_gradcheck_point(1.5, 1.0, 2, 1, 1e-2)); // tie at .5
        assert!(!safe_gradcheck_point(1.0, 1.0, 2, 1, 1e-2)); // at Qp
        assert!(!safe_gradcheck_point(-2.0, 1.0, 2, 1, 1e-2)); // at -Qn
        assert!(safe_gradcheck_point(0.3, 1.0, 2, 1, 1e-2));
    }
}
