//! The native training loop: drives [`NativeTrainModel`] over the data
//! pipeline per an [`ExperimentConfig`], implementing the paper's protocol
//! with **no XLA/PJRT** — fp32 pretrain → per-precision fine-tune with
//! Section-2.1 step-size initialization, SGD + momentum + per-precision
//! weight decay, cosine or step LR decay.
//!
//! The epoch loop itself is shared with the XLA trainer through
//! [`crate::train::TrainBackend`] / [`crate::train::fit_backend`], so both
//! paths emit identical [`History`] records and checkpoint layouts.

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::ExperimentConfig;
use crate::data::Dataset;
use crate::quant::lsq::qrange;
use crate::runtime::kernels::Workspace;
use crate::runtime::Manifest;
use crate::tensor::{Checkpoint, Tensor};
use crate::train::metrics::{topk_correct, History};
use crate::train::state::TrainState;
use crate::train::{fit_backend, FitReport, TrainBackend};

use super::backward::NativeTrainModel;
use super::optim::sgd_step;

/// Pure-Rust trainer: the native sibling of the XLA `Trainer`. Owns its
/// [`Manifest`] (no engine, no artifacts beyond `manifest.json` +
/// `params.bin`) and runs the hand-written backward pass of
/// [`NativeTrainModel`].
pub struct NativeTrainer {
    manifest: Manifest,
    model: NativeTrainModel,
    /// Kernel-layer scratch arena, reused across every step/eval — the
    /// steady-state train loop draws all GEMM/im2col/tape buffers from
    /// here instead of allocating (DESIGN.md §Kernel-layer).
    ws: Workspace,
    /// Experiment configuration this run follows.
    pub cfg: ExperimentConfig,
    /// Master parameters + momentum buffers.
    pub state: TrainState,
    /// Step/eval records, identical in shape to the XLA trainer's.
    pub history: History,
    /// Per-epoch progress printing.
    pub verbose: bool,
    /// Wall time spent in forward+backward+update (the native analogue of
    /// the XLA trainer's `exec_seconds`).
    pub exec_seconds: f64,
}

impl NativeTrainer {
    /// Build a trainer over the manifest in `cfg.artifacts_dir`, mirroring
    /// the XLA `Trainer::new` state protocol: fresh init, same-family
    /// resume, or fp32→quantized fine-tune with Section-2.1 step-size
    /// re-initialization.
    pub fn new(cfg: ExperimentConfig) -> Result<NativeTrainer> {
        cfg.validate()?;
        let manifest = Manifest::load(Path::new(&cfg.artifacts_dir))?;
        let family = cfg.family();
        let model = NativeTrainModel::build(&manifest, &family, &cfg.method, &cfg.gscale)?;
        // Labels come from cfg.data.classes; the model's logit count must
        // cover them, or softmax indexing would panic mid-training.
        let fam_classes = manifest.family(&family)?.num_classes;
        if cfg.data.classes > fam_classes {
            bail!(
                "config asks for {} data classes but family {family} has only \
                 {fam_classes} logits",
                cfg.data.classes
            );
        }

        let state;
        let needs_init_quant;
        if cfg.init_from.is_empty() {
            state = TrainState::fresh(&manifest, &family)?;
            needs_init_quant = cfg.bits < 32;
        } else {
            let ck = Checkpoint::load(Path::new(&cfg.init_from))
                .with_context(|| format!("init_from={}", cfg.init_from))?;
            if ck.meta_str("family") == Some(family.as_str()) {
                state = TrainState::load(&manifest, Path::new(&cfg.init_from))?;
                needs_init_quant = false;
            } else {
                let (s, copied) = TrainState::from_fp32_checkpoint(&manifest, &family, &ck)?;
                state = s;
                needs_init_quant = cfg.bits < 32;
                if copied == 0 {
                    bail!("no params copied from {}", cfg.init_from);
                }
            }
        }

        let mut tr = NativeTrainer {
            manifest,
            model,
            ws: Workspace::new(),
            cfg,
            state,
            history: History::default(),
            verbose: true,
            exec_seconds: 0.0,
        };
        if needs_init_quant {
            tr.run_init_quant()?;
        }
        Ok(tr)
    }

    /// The manifest this trainer was opened over.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Cap this trainer's intra-op kernel threads (0 = hardware count).
    /// The sweep coordinator calls this with `cores / workers` so
    /// `workers × intra-op threads` never oversubscribes the host —
    /// the training-side mirror of the serve layer's
    /// [`crate::runtime::PrepareOptions::intra_op_threads`].
    pub fn set_threads(&mut self, threads: usize) {
        self.ws.set_threads(threads);
    }

    /// Section-2.1 step-size initialization, the native mirror of the
    /// `init_quant` artifact: every weight step becomes `2⟨|w|⟩/√Qp` over
    /// the *current* weights, every activation step `2⟨|v|⟩/√Qp` over the
    /// first (unaugmented) training batch via the full-precision collect
    /// pass.
    fn run_init_quant(&mut self) -> Result<()> {
        let family = self.cfg.family();
        let fam = self.manifest.family(&family)?.clone();
        // sw from the current weights.
        let bits_of: std::collections::BTreeMap<&str, u32> =
            fam.layer_meta.iter().map(|l| (l.name.as_str(), l.bits)).collect();
        for name in fam.step_names("step_w") {
            let scope = name.strip_suffix(".sw").unwrap_or(&name).to_string();
            let bits = *bits_of
                .get(scope.as_str())
                .ok_or_else(|| anyhow::anyhow!("no layer_meta for {scope}"))?;
            let (_, qp) = qrange(bits, true);
            let w = self.state.param(&fam, &format!("{scope}.w"))?.f32s()?;
            let sw = crate::quant::lsq::step_init(w, qp).max(1e-8);
            self.state.set_param(&fam, &name, Tensor::scalar_f32(sw))?;
        }
        // sa from the first batch of (full-precision) activations.
        let ds = Dataset::train(&self.cfg.data);
        let batch = self.manifest.batch.max(1).min(ds.size.max(1));
        let idx: Vec<usize> = (0..batch).collect();
        let b = ds.batch_from_indices(&idx, batch);
        let stats =
            self.model.collect_act_stats(&mut self.ws, &self.state.params, b.x.f32s()?, batch)?;
        for st in stats {
            let sa = (2.0 * st.mean_abs / (st.qp.max(1) as f64).sqrt()).max(1e-8) as f32;
            self.state.set_param(&fam, &st.sa_name, Tensor::scalar_f32(sa))?;
        }
        Ok(())
    }

    /// One optimizer step on a prepared batch; returns `(loss, acc)`.
    pub fn step(&mut self, x: Tensor, y: Tensor, lr: f64, wd: f64) -> Result<(f64, f64)> {
        let t0 = Instant::now();
        let rows = y.numel();
        let out = self
            .model
            .loss_and_grads(&mut self.ws, &self.state.params, x.f32s()?, y.i32s()?, rows)?;
        let family = self.cfg.family();
        let fam = self.manifest.family(&family)?;
        sgd_step(fam, &mut self.state.params, &mut self.state.moms, &out.grads, lr, wd)?;
        for (idx, t) in out.state_updates {
            self.state.params[idx] = t;
        }
        self.state.step += 1;
        self.exec_seconds += t0.elapsed().as_secs_f64();
        Ok((out.loss, out.ncorrect as f64 / rows as f64))
    }

    /// Full pass over the test split; returns `(loss, top1%, top5%)`.
    pub fn evaluate(&mut self) -> Result<(f64, f64, f64)> {
        let ds = Dataset::test(&self.cfg.data);
        let batch = self.manifest.batch.max(1);
        let classes = self.model.num_classes();
        let mut total = 0usize;
        let mut top1 = 0usize;
        let mut top5 = 0usize;
        let mut loss_sum = 0.0f64;
        let mut nb = 0usize;
        for b in ds.eval_batches(batch) {
            let rows = b.y.numel();
            let logits =
                self.model.forward_eval(&mut self.ws, &self.state.params, b.x.f32s()?, rows)?;
            let labels = b.y.i32s()?;
            // Like the XLA eval artifact: loss over the whole (padded)
            // batch, accuracy over the real rows only.
            let (loss, _) = super::grad::softmax_xent_loss(&logits, labels, classes, rows);
            top1 += topk_correct(&logits, labels, classes, 1, b.real);
            top5 += topk_correct(&logits, labels, classes, 5, b.real);
            total += b.real;
            loss_sum += loss;
            nb += 1;
        }
        Ok((
            loss_sum / nb.max(1) as f64,
            100.0 * top1 as f64 / total.max(1) as f64,
            100.0 * top5 as f64 / total.max(1) as f64,
        ))
    }

    /// The full training run per config (shared loop, see
    /// [`crate::train::fit_backend`]).
    pub fn fit(&mut self) -> Result<FitReport> {
        fit_backend(self)
    }
}

impl TrainBackend for NativeTrainer {
    fn cfg(&self) -> &ExperimentConfig {
        &self.cfg
    }

    fn train_batch(&self) -> usize {
        self.manifest.batch.max(1)
    }

    fn verbose(&self) -> bool {
        self.verbose
    }

    fn state(&self) -> &TrainState {
        &self.state
    }

    fn history(&self) -> &History {
        &self.history
    }

    fn history_mut(&mut self) -> &mut History {
        &mut self.history
    }

    fn step(&mut self, x: Tensor, y: Tensor, lr: f64, wd: f64) -> Result<(f64, f64)> {
        NativeTrainer::step(self, x, y, lr, wd)
    }

    fn evaluate(&mut self) -> Result<(f64, f64, f64)> {
        NativeTrainer::evaluate(self)
    }

    fn save_checkpoint(&self, path: &Path) -> Result<()> {
        let fam = self.manifest.family(&self.cfg.family())?;
        self.state.save(fam, path)
    }
}
