//! Native pure-Rust **training** subsystem: the hand-written backward pass
//! that makes 2/3/4/8-bit LSQ training run with no XLA/PJRT — the
//! training-side counterpart of [`crate::runtime::native`].
//!
//! The paper's core contribution is the training-time step-size gradient
//! (Eq. 3 with the `g = 1/√(N·Qp)` scale, Sections 2.2-2.3); this module
//! reproduces it natively:
//!
//! * [`grad`] — quantizer gradient estimators (LSQ + the QIL/PACT/fixed
//!   ablation variants), gradient-scale modes, softmax cross-entropy, and
//!   the finite-difference grad-check harness (`tests/grad_check.rs`);
//! * [`backward`] — [`backward::NativeTrainModel`]: tape-recorded forward
//!   + hand-written backward over the model-zoo arch IR. All compute
//!   (GEMMs, im2col adjoint, pooling, batch norm) routes through the
//!   shared kernel layer [`crate::runtime::kernels`], so this module is
//!   tape bookkeeping + quantizer adjoints only;
//! * [`optim`] — SGD + momentum + role-aware weight decay, mirroring
//!   `python/compile/train.py`;
//! * [`r#loop`] — [`NativeTrainer`], driving the shared
//!   [`crate::train::fit_backend`] epoch loop.

pub mod backward;
pub mod grad;
pub mod optim;
#[path = "loop.rs"]
pub mod r#loop;

pub use backward::{NativeTrainModel, StepOutput};
pub use r#loop::NativeTrainer;
