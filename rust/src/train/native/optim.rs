//! SGD + momentum + role-aware weight decay, mirroring `_sgd` in
//! `python/compile/train.py`: decay applies to conv/fc **weights only** —
//! biases, BN affines and the step sizes train decay-free (the paper's
//! recipe, Section 2.3), and BN running stats carry no gradient at all.

use anyhow::{ensure, Result};

use crate::runtime::Family;
use crate::tensor::Tensor;

/// Momentum coefficient, shared with `train.MOMENTUM`.
pub const MOMENTUM: f32 = 0.9;

/// One in-place SGD step: for every gradient-bearing parameter (in
/// `Family::grad_names` order), `g ← grad (+ wd·p for weights)`,
/// `m ← 0.9·m + g`, `p ← p − lr·m`.
///
/// `params` follow `Family::param_names`; `moms` and `grads` follow
/// `Family::grad_names`.
pub fn sgd_step(
    fam: &Family,
    params: &mut [Tensor],
    moms: &mut [Tensor],
    grads: &[Tensor],
    lr: f64,
    wd: f64,
) -> Result<()> {
    ensure!(params.len() == fam.param_names.len(), "params arity");
    ensure!(moms.len() == fam.grad_names.len(), "momentum arity");
    ensure!(grads.len() == fam.grad_names.len(), "gradient arity");
    let lr = lr as f32;
    let wd = wd as f32;
    for (gi, name) in fam.grad_names.iter().enumerate() {
        let pi = fam
            .param_names
            .iter()
            .position(|n| n == name)
            .ok_or_else(|| anyhow::anyhow!("grad name {name} not in params"))?;
        let decay = fam.roles.get(name).map(String::as_str) == Some("weight");
        let g = grads[gi].f32s()?;
        let m = moms[gi].f32s_mut()?;
        let p = params[pi].f32s_mut()?;
        ensure!(
            g.len() == p.len() && m.len() == p.len(),
            "{name}: grad/mom/param length mismatch ({} / {} / {})",
            g.len(),
            m.len(),
            p.len()
        );
        for i in 0..p.len() {
            let mut gv = g[i];
            if decay {
                gv += wd * p[i];
            }
            m[i] = MOMENTUM * m[i] + gv;
            p[i] -= lr * m[i];
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;

    fn toy_family() -> Family {
        let mut roles = BTreeMap::new();
        roles.insert("w".to_string(), "weight".to_string());
        roles.insert("b".to_string(), "bias".to_string());
        roles.insert("s".to_string(), "state".to_string());
        let mut shapes = BTreeMap::new();
        shapes.insert("w".to_string(), vec![2]);
        shapes.insert("b".to_string(), vec![2]);
        shapes.insert("s".to_string(), vec![1]);
        Family {
            name: "toy".into(),
            model: "mlp".into(),
            qbits: 32,
            num_classes: 2,
            params_bin: String::new(),
            n_matmul: 1,
            param_names: vec!["b".into(), "s".into(), "w".into()],
            grad_names: vec!["b".into(), "w".into()],
            roles,
            shapes,
            layer_meta: Vec::new(),
        }
    }

    #[test]
    fn decay_hits_weights_only() {
        let fam = toy_family();
        let mut params = vec![
            Tensor::from_f32(&[2], vec![1.0, 1.0]), // b
            Tensor::from_f32(&[1], vec![5.0]),      // s (state: untouched)
            Tensor::from_f32(&[2], vec![1.0, 1.0]), // w
        ];
        let mut moms = vec![Tensor::zeros(&[2]), Tensor::zeros(&[2])];
        let grads = vec![Tensor::zeros(&[2]), Tensor::zeros(&[2])];
        sgd_step(&fam, &mut params, &mut moms, &grads, 1.0, 0.1).unwrap();
        // bias: zero grad, no decay -> unchanged
        assert_eq!(params[0].f32s().unwrap(), &[1.0, 1.0]);
        // state: untouched
        assert_eq!(params[1].f32s().unwrap(), &[5.0]);
        // weight: g = 0 + 0.1*1, p = 1 - 1.0*0.1
        assert!((params[2].f32s().unwrap()[0] - 0.9).abs() < 1e-6);
    }

    #[test]
    fn momentum_accumulates() {
        let fam = toy_family();
        let mut params = vec![
            Tensor::from_f32(&[2], vec![0.0, 0.0]),
            Tensor::from_f32(&[1], vec![0.0]),
            Tensor::from_f32(&[2], vec![0.0, 0.0]),
        ];
        let mut moms = vec![Tensor::zeros(&[2]), Tensor::zeros(&[2])];
        let grads = vec![
            Tensor::from_f32(&[2], vec![1.0, 0.0]),
            Tensor::from_f32(&[2], vec![0.0, 0.0]),
        ];
        sgd_step(&fam, &mut params, &mut moms, &grads, 0.1, 0.0).unwrap();
        sgd_step(&fam, &mut params, &mut moms, &grads, 0.1, 0.0).unwrap();
        // m1 = 1, m2 = 1.9 -> p = -(0.1 + 0.19)
        assert!((params[0].f32s().unwrap()[0] + 0.29).abs() < 1e-6);
        assert!((moms[0].f32s().unwrap()[0] - 1.9).abs() < 1e-6);
    }
}
