//! Training state: named parameter/momentum tensors for one model family,
//! checkpointing, and the fp32→quantized fine-tune mapping (paper protocol:
//! all quantized runs start from a trained full-precision model).

use std::path::Path;

use anyhow::{bail, Result};

use crate::runtime::{Family, Manifest};
use crate::tensor::{Checkpoint, Tensor};
use crate::util::json::Json;

#[derive(Clone)]
pub struct TrainState {
    pub family: String,
    /// One tensor per `Family::param_names`, in order.
    pub params: Vec<Tensor>,
    /// One tensor per `Family::grad_names`, in order.
    pub moms: Vec<Tensor>,
    pub step: usize,
}

impl TrainState {
    /// Fresh state from the AOT initial parameters.
    pub fn fresh(manifest: &Manifest, family: &str) -> Result<TrainState> {
        let fam = manifest.family(family)?;
        let params = manifest.load_initial_params(family)?;
        let moms = zero_moms(fam, &params);
        Ok(TrainState { family: family.to_string(), params, moms, step: 0 })
    }

    /// Paper fine-tune protocol: take every parameter that exists in the
    /// source checkpoint (weights, biases, BN state — the fp32 model), keep
    /// family defaults for the rest (the step sizes, which the init_quant
    /// artifact then re-derives from the loaded weights + first batch).
    pub fn from_fp32_checkpoint(
        manifest: &Manifest,
        family: &str,
        ckpt: &Checkpoint,
    ) -> Result<(TrainState, usize)> {
        let fam = manifest.family(family)?;
        let mut params = manifest.load_initial_params(family)?;
        let mut copied = 0usize;
        for (i, name) in fam.param_names.iter().enumerate() {
            if let Some(src) = ckpt.tensors.get(name) {
                if src.shape != params[i].shape {
                    bail!(
                        "checkpoint tensor {name} shape {:?} != family shape {:?}",
                        src.shape,
                        params[i].shape
                    );
                }
                params[i] = src.clone();
                copied += 1;
            }
        }
        if copied == 0 {
            bail!("checkpoint shares no parameters with family {family}");
        }
        let moms = zero_moms(fam, &params);
        Ok((TrainState { family: family.to_string(), params, moms, step: 0 }, copied))
    }

    pub fn param(&self, fam: &Family, name: &str) -> Result<&Tensor> {
        let i = fam
            .param_names
            .iter()
            .position(|n| n == name)
            .ok_or_else(|| anyhow::anyhow!("no param {name} in {}", self.family))?;
        Ok(&self.params[i])
    }

    /// Replace parameter `name` (used by the native Section-2.1 step-size
    /// initialization, the in-process mirror of the `init_quant` artifact).
    pub fn set_param(&mut self, fam: &Family, name: &str, t: Tensor) -> Result<()> {
        let i = fam
            .param_names
            .iter()
            .position(|n| n == name)
            .ok_or_else(|| anyhow::anyhow!("no param {name} in {}", self.family))?;
        if t.numel() != self.params[i].numel() {
            bail!(
                "set_param {name}: {} elements, expected {}",
                t.numel(),
                self.params[i].numel()
            );
        }
        self.params[i] = t;
        Ok(())
    }

    pub fn to_checkpoint(&self, fam: &Family) -> Checkpoint {
        let mut ck = Checkpoint::new();
        for (name, t) in fam.param_names.iter().zip(&self.params) {
            ck.insert(name, t.clone());
        }
        for (name, t) in fam.grad_names.iter().zip(&self.moms) {
            ck.insert(&format!("mom::{name}"), t.clone());
        }
        ck.meta.insert("family".into(), Json::str(self.family.clone()));
        ck.meta.insert("step".into(), Json::num(self.step as f64));
        ck
    }

    pub fn save(&self, fam: &Family, path: &Path) -> Result<()> {
        self.to_checkpoint(fam).save(path)
    }

    /// Restore params+momentum from a same-family checkpoint.
    pub fn load(manifest: &Manifest, path: &Path) -> Result<TrainState> {
        let ck = Checkpoint::load(path)?;
        let family = ck
            .meta_str("family")
            .ok_or_else(|| anyhow::anyhow!("{path:?}: checkpoint missing family meta"))?
            .to_string();
        let fam = manifest.family(&family)?;
        let mut params = Vec::with_capacity(fam.param_names.len());
        for name in &fam.param_names {
            params.push(ck.get(name)?.clone());
        }
        let mut moms = Vec::with_capacity(fam.grad_names.len());
        for name in &fam.grad_names {
            match ck.tensors.get(&format!("mom::{name}")) {
                Some(t) => moms.push(t.clone()),
                None => {
                    let shape = fam.shapes.get(name).cloned().unwrap_or_default();
                    moms.push(Tensor::zeros(&shape));
                }
            }
        }
        let step = ck.meta.get("step").and_then(Json::as_usize).unwrap_or(0);
        Ok(TrainState { family, params, moms, step })
    }
}

fn zero_moms(fam: &Family, _params: &[Tensor]) -> Vec<Tensor> {
    fam.grad_names
        .iter()
        .map(|n| Tensor::zeros(fam.shapes.get(n).map(Vec::as_slice).unwrap_or(&[])))
        .collect()
}
