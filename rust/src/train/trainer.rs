//! The training loop: drives the AOT train/eval artifacts over the data
//! pipeline per an `ExperimentConfig`, implementing the paper's protocol —
//! fp32 pretrain → per-precision fine-tune with step-size initialization
//! (Section 2.1), SGD + momentum + per-precision weight decay, cosine or
//! step LR decay, optional same-architecture knowledge distillation.
//!
//! Hot-loop structure: the data loader prefetches on its own thread; the
//! coordinator assembles the positional input vector (params, momentum,
//! [teacher], batch, lr, wd) and feeds each step's outputs back as the next
//! step's inputs. Everything heavier than a memcpy happens inside XLA.

use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::ExperimentConfig;
use crate::data::Dataset;
use crate::runtime::{Engine, Executable};
use crate::tensor::{Checkpoint, Tensor};
use crate::train::metrics::{topk_correct, History};
use crate::train::state::TrainState;
use crate::train::{fit_backend, FitReport, TrainBackend};

pub struct Trainer<'e> {
    pub engine: &'e Engine,
    pub cfg: ExperimentConfig,
    pub state: TrainState,
    pub history: History,
    train_exe: Rc<Executable>,
    eval_exe: Rc<Executable>,
    teacher_params: Option<Vec<Tensor>>,
    pub verbose: bool,
    /// Wall time spent outside `Executable::run` in the train loop (driver
    /// overhead; perf target <5% of step time — EXPERIMENTS.md §Perf).
    pub driver_seconds: f64,
    pub exec_seconds: f64,
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e Engine, cfg: ExperimentConfig) -> Result<Trainer<'e>> {
        cfg.validate()?;
        let family = cfg.family();
        let manifest = engine.manifest();
        let fam = manifest.family(&family)?.clone();

        // -- initial state ----------------------------------------------------
        let state;
        let needs_init_quant;
        if cfg.init_from.is_empty() {
            state = TrainState::fresh(manifest, &family)?;
            needs_init_quant = cfg.bits < 32;
        } else {
            let ck = Checkpoint::load(Path::new(&cfg.init_from))
                .with_context(|| format!("init_from={}", cfg.init_from))?;
            if ck.meta_str("family") == Some(family.as_str()) {
                // resuming same-family training
                state = TrainState::load(manifest, Path::new(&cfg.init_from))?;
                needs_init_quant = false;
            } else {
                let (s, copied) = TrainState::from_fp32_checkpoint(manifest, &family, &ck)?;
                state = s;
                needs_init_quant = cfg.bits < 32;
                if copied == 0 {
                    bail!("no params copied from {}", cfg.init_from);
                }
            }
        }

        // -- artifacts ---------------------------------------------------------
        let kind = if cfg.distill { "train_kd" } else { "train" };
        let train_exe = engine.load_kind(
            kind,
            &family,
            Some(cfg.method.as_str()),
            Some(cfg.gscale.as_str()),
        )?;
        let eval_exe = engine.load_kind("eval", &family, None, None)?;

        // -- teacher (frozen fp32 weights of the same architecture) -------------
        let teacher_params = if cfg.distill {
            let tfam = train_exe
                .meta
                .teacher_family
                .clone()
                .ok_or_else(|| anyhow::anyhow!("kd artifact missing teacher_family"))?;
            let src = if cfg.init_from.is_empty() {
                manifest.load_initial_params(&tfam)?
            } else {
                let ck = Checkpoint::load(Path::new(&cfg.init_from))?;
                let tf = manifest.family(&tfam)?;
                let mut ps = manifest.load_initial_params(&tfam)?;
                for (i, name) in tf.param_names.iter().enumerate() {
                    if let Some(t) = ck.tensors.get(name) {
                        ps[i] = t.clone();
                    }
                }
                ps
            };
            Some(src)
        } else {
            None
        };

        let mut tr = Trainer {
            engine,
            cfg,
            state,
            history: History::default(),
            train_exe,
            eval_exe,
            teacher_params,
            verbose: true,
            driver_seconds: 0.0,
            exec_seconds: 0.0,
        };

        // -- step-size init from weights + first batch (Section 2.1) -----------
        if needs_init_quant {
            tr.run_init_quant()?;
        }
        let _ = fam;
        Ok(tr)
    }

    /// Run the init_quant artifact: sw from current weights, sa from the
    /// first (unaugmented) training batch.
    fn run_init_quant(&mut self) -> Result<()> {
        let exe = self.engine.load_kind("init_quant", &self.cfg.family(), None, None)?;
        let ds = Dataset::train(&self.cfg.data);
        let batch = exe.meta.batch;
        let idx: Vec<usize> = (0..batch.min(ds.size)).collect();
        let b = ds.batch_from_indices(&idx, batch);
        let mut inputs = self.state.params.clone();
        inputs.push(b.x);
        let out = exe.run(&inputs)?;
        if out.len() != self.state.params.len() {
            let want = self.state.params.len();
            bail!("init_quant returned {} tensors, expected {want}", out.len());
        }
        self.state.params = out;
        Ok(())
    }

    /// One optimizer step on a prepared batch; returns (loss, acc).
    pub fn step(&mut self, x: Tensor, y: Tensor, lr: f64, wd: f64) -> Result<(f64, f64)> {
        let t_drv = Instant::now();
        let p = self.state.params.len();
        let g = self.state.moms.len();
        let batch = y.numel();
        let mut inputs: Vec<Tensor> =
            Vec::with_capacity(p + g + self.teacher_params.as_ref().map_or(0, Vec::len) + 4);
        inputs.extend(self.state.params.iter().cloned());
        inputs.extend(self.state.moms.iter().cloned());
        if let Some(tp) = &self.teacher_params {
            inputs.extend(tp.iter().cloned());
        }
        inputs.push(x);
        inputs.push(y);
        inputs.push(Tensor::scalar_f32(lr as f32));
        inputs.push(Tensor::scalar_f32(wd as f32));

        let t_exec = Instant::now();
        self.driver_seconds += (t_exec - t_drv).as_secs_f64();
        let mut out = self.train_exe.run(&inputs)?;
        self.exec_seconds += t_exec.elapsed().as_secs_f64();

        let t_post = Instant::now();
        if out.len() < p + g + 2 {
            bail!("train step returned {} outputs, expected >= {}", out.len(), p + g + 2);
        }
        let ncorrect = out[p + g + 1].item_f32()? as f64;
        let loss = out[p + g].item_f32()? as f64;
        out.truncate(p + g);
        let moms = out.split_off(p);
        self.state.params = out;
        self.state.moms = moms;
        self.state.step += 1;
        self.driver_seconds += t_post.elapsed().as_secs_f64();
        Ok((loss, ncorrect / batch as f64))
    }

    /// Full pass over the test split; returns (loss, top1%, top5%).
    pub fn evaluate(&mut self) -> Result<(f64, f64, f64)> {
        let ds = Dataset::test(&self.cfg.data);
        let batch = self.eval_exe.meta.batch;
        let classes = self
            .engine
            .manifest()
            .family(&self.cfg.family())?
            .num_classes;
        let mut total = 0usize;
        let mut top1 = 0usize;
        let mut top5 = 0usize;
        let mut loss_sum = 0.0f64;
        let mut nb = 0usize;
        for b in ds.eval_batches(batch) {
            let mut inputs = self.state.params.clone();
            let y = b.y.clone();
            inputs.push(b.x);
            inputs.push(b.y);
            let out = self.eval_exe.run(&inputs)?;
            let logits = out[2].f32s()?;
            let labels = y.i32s()?;
            top1 += topk_correct(logits, labels, classes, 1, b.real);
            top5 += topk_correct(logits, labels, classes, 5, b.real);
            total += b.real;
            loss_sum += out[0].item_f32()? as f64;
            nb += 1;
        }
        Ok((
            loss_sum / nb.max(1) as f64,
            100.0 * top1 as f64 / total.max(1) as f64,
            100.0 * top5 as f64 / total.max(1) as f64,
        ))
    }

    /// The full training run per config (shared loop, see
    /// [`crate::train::fit_backend`]); saves history + final checkpoint
    /// under `out_dir/name/`.
    pub fn fit(&mut self) -> Result<FitReport> {
        fit_backend(self)
    }

    /// Fraction of loop wall time spent outside XLA execution.
    pub fn driver_overhead(&self) -> f64 {
        let total = self.driver_seconds + self.exec_seconds;
        if total == 0.0 {
            0.0
        } else {
            self.driver_seconds / total
        }
    }
}

impl TrainBackend for Trainer<'_> {
    fn cfg(&self) -> &ExperimentConfig {
        &self.cfg
    }

    fn train_batch(&self) -> usize {
        self.train_exe.meta.batch
    }

    fn verbose(&self) -> bool {
        self.verbose
    }

    fn state(&self) -> &TrainState {
        &self.state
    }

    fn history(&self) -> &History {
        &self.history
    }

    fn history_mut(&mut self) -> &mut History {
        &mut self.history
    }

    fn step(&mut self, x: Tensor, y: Tensor, lr: f64, wd: f64) -> Result<(f64, f64)> {
        Trainer::step(self, x, y, lr, wd)
    }

    fn evaluate(&mut self) -> Result<(f64, f64, f64)> {
        Trainer::evaluate(self)
    }

    fn save_checkpoint(&self, path: &Path) -> Result<()> {
        let fam = self.engine.manifest().family(&self.cfg.family())?.clone();
        self.state.save(&fam, path)
    }
}
