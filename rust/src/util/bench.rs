//! Micro-benchmark harness (offline substrate — no criterion in the vendored
//! crate universe). Used by the `rust/benches/*.rs` binaries.
//!
//! Methodology: warmup until the clock stabilizes, then fixed-duration
//! measurement batches; reports mean / p50 / p95 / min over per-iteration
//! times and writes one CSV row per benchmark to `target/bench_results.csv`
//! so EXPERIMENTS.md §Perf entries are regenerable. [`Bench::write_json`]
//! additionally emits the whole suite as one machine-readable JSON
//! document (the perf-trajectory format `BENCH_*.json` files use).

use std::time::{Duration, Instant};

use super::json::Json;
use super::stats::{mean, percentile};

pub struct BenchOpts {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_iters: u64,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            min_iters: 10,
        }
    }
}

pub struct Bench {
    suite: String,
    opts: BenchOpts,
    results: Vec<(String, BenchResult)>,
    /// Suite-level metadata key/values ([`Bench::set_meta`]), emitted as
    /// top-level JSON fields (e.g. the SIMD dispatch level of the run).
    meta: Vec<(String, String)>,
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    /// Optional user-provided work units per iteration (elements, bytes…)
    /// enabling throughput reporting.
    pub units_per_iter: f64,
    /// Derived per-row columns ([`Bench::annotate`]) — ratio columns like
    /// `speedup_vs_serial`, emitted as extra JSON fields so the trajectory
    /// file is self-describing without hand-diffing rows.
    pub extras: Vec<(String, f64)>,
    /// Per-row string columns ([`Bench::annotate_str`]) — e.g. the
    /// *effective* SIMD level of a forced-scalar row, which suite-level
    /// [`Bench::set_meta`] cannot express (it describes the whole run).
    pub extras_str: Vec<(String, String)>,
}

impl BenchResult {
    pub fn throughput(&self) -> f64 {
        if self.units_per_iter > 0.0 {
            self.units_per_iter / (self.mean_ns * 1e-9)
        } else {
            f64::NAN
        }
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

impl Bench {
    pub fn new(suite: &str) -> Self {
        let mut opts = BenchOpts::default();
        // Fast mode for CI/tests: LSQNET_BENCH_FAST=1 shrinks measurement
        // (shared truthy rule — `LSQNET_BENCH_FAST=0` means off, like
        // every other LSQNET_* knob).
        if super::env_truthy("LSQNET_BENCH_FAST") {
            opts.warmup = Duration::from_millis(50);
            opts.measure = Duration::from_millis(200);
        }
        Bench { suite: suite.to_string(), opts, results: Vec::new(), meta: Vec::new() }
    }

    pub fn with_opts(suite: &str, opts: BenchOpts) -> Self {
        Bench { suite: suite.to_string(), opts, results: Vec::new(), meta: Vec::new() }
    }

    /// Run `f` repeatedly; one call = one iteration.
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> BenchResult {
        self.bench_units(name, 0.0, f)
    }

    pub fn bench_units<F: FnMut()>(
        &mut self,
        name: &str,
        units_per_iter: f64,
        mut f: F,
    ) -> BenchResult {
        // Warmup.
        let t0 = Instant::now();
        while t0.elapsed() < self.opts.warmup {
            f();
        }
        // Measure.
        let mut times_ns: Vec<f64> = Vec::new();
        let t1 = Instant::now();
        while t1.elapsed() < self.opts.measure || (times_ns.len() as u64) < self.opts.min_iters {
            let s = Instant::now();
            f();
            times_ns.push(s.elapsed().as_nanos() as f64);
            if times_ns.len() > 5_000_000 {
                break;
            }
        }
        let res = BenchResult {
            iters: times_ns.len() as u64,
            mean_ns: mean(&times_ns),
            p50_ns: percentile(&times_ns, 50.0),
            p95_ns: percentile(&times_ns, 95.0),
            min_ns: times_ns.iter().cloned().fold(f64::INFINITY, f64::min),
            units_per_iter,
            extras: Vec::new(),
            extras_str: Vec::new(),
        };
        println!(
            "{:<40} {:>12} iters  mean {:>12}  p50 {:>12}  p95 {:>12}{}",
            format!("{}/{}", self.suite, name),
            res.iters,
            fmt_ns(res.mean_ns),
            fmt_ns(res.p50_ns),
            fmt_ns(res.p95_ns),
            if units_per_iter > 0.0 {
                format!("  {:>10.2e} units/s", res.throughput())
            } else {
                String::new()
            }
        );
        self.results.push((name.to_string(), res.clone()));
        res
    }

    /// Record externally-measured per-event times (nanoseconds) as one
    /// result row. This is how open-loop measurements enter the suite:
    /// [`Bench::bench`] times a closure in a closed loop (next iteration
    /// waits for the previous), but an open-loop load generator paces
    /// sends on a schedule and collects each request's latency itself —
    /// the harness only aggregates. Prints the standard row and keeps the
    /// result for [`Bench::to_json`] like any other entry; extra
    /// percentiles (p99/p999) go through [`Bench::annotate`]. Empty input
    /// records an all-zero row rather than NaN (JSON has no NaN).
    pub fn record_ns(&mut self, name: &str, times_ns: &[f64], units_per_iter: f64) -> BenchResult {
        let res = if times_ns.is_empty() {
            BenchResult {
                iters: 0,
                mean_ns: 0.0,
                p50_ns: 0.0,
                p95_ns: 0.0,
                min_ns: 0.0,
                units_per_iter,
                extras: Vec::new(),
                extras_str: Vec::new(),
            }
        } else {
            BenchResult {
                iters: times_ns.len() as u64,
                mean_ns: mean(times_ns),
                p50_ns: percentile(times_ns, 50.0),
                p95_ns: percentile(times_ns, 95.0),
                min_ns: times_ns.iter().cloned().fold(f64::INFINITY, f64::min),
                units_per_iter,
                extras: Vec::new(),
                extras_str: Vec::new(),
            }
        };
        println!(
            "{:<40} {:>12} iters  mean {:>12}  p50 {:>12}  p95 {:>12}{}",
            format!("{}/{}", self.suite, name),
            res.iters,
            fmt_ns(res.mean_ns),
            fmt_ns(res.p50_ns),
            fmt_ns(res.p95_ns),
            if units_per_iter > 0.0 && res.iters > 0 {
                format!("  {:>10.2e} units/s", res.throughput())
            } else {
                String::new()
            }
        );
        self.results.push((name.to_string(), res.clone()));
        res
    }

    /// All results recorded so far, in run order.
    pub fn results(&self) -> &[(String, BenchResult)] {
        &self.results
    }

    /// Attach a derived ratio column to the most recent result named
    /// `name` (e.g. `speedup_vs_serial`, `panel_vs_fused`). The value is
    /// emitted as an extra JSON field on that row, so trajectory files
    /// carry their own comparisons instead of requiring hand-diffing.
    /// Non-finite values are dropped (JSON has no NaN); an unknown name is
    /// a no-op.
    pub fn annotate(&mut self, name: &str, key: &str, value: f64) {
        if !value.is_finite() {
            return;
        }
        if let Some((_, r)) = self.results.iter_mut().rev().find(|(n, _)| n == name) {
            r.extras.push((key.to_string(), value));
        }
    }

    /// Attach a string column to the most recent result named `name`
    /// (e.g. `simd` → the *effective* dispatch level of that row, which
    /// may differ from the suite-level [`Bench::set_meta`] value when the
    /// row pinned a level in-process). Emitted as an extra JSON string
    /// field on that row; an unknown name is a no-op.
    pub fn annotate_str(&mut self, name: &str, key: &str, value: &str) {
        if let Some((_, r)) = self.results.iter_mut().rev().find(|(n, _)| n == name) {
            r.extras_str.push((key.to_string(), value.to_string()));
        }
    }

    /// Set a suite-level metadata string (e.g. `simd` → the dispatch
    /// level of this run), emitted as a top-level JSON field. Re-setting a
    /// key overwrites it.
    pub fn set_meta(&mut self, key: &str, value: &str) {
        if let Some((_, v)) = self.meta.iter_mut().find(|(k, _)| k == key) {
            *v = value.to_string();
        } else {
            self.meta.push((key.to_string(), value.to_string()));
        }
    }

    /// Serialize the whole suite as one machine-readable JSON document:
    /// `{suite, threads_available, <meta…>, results: [{name, iters,
    /// mean_ns, p50_ns, p95_ns, min_ns, units_per_iter, units_per_sec?,
    /// <extras…>}]}` — the format the repo-root `BENCH_*.json`
    /// perf-trajectory files use. `units_per_sec` is present only for
    /// [`Bench::bench_units`] entries (JSON has no NaN); `<extras…>` are
    /// the [`Bench::annotate`] ratio columns and the
    /// [`Bench::annotate_str`] string columns.
    pub fn to_json(&self) -> Json {
        let results: Vec<Json> = self
            .results
            .iter()
            .map(|(name, r)| {
                let mut fields = vec![
                    ("name", Json::str(name.clone())),
                    ("iters", Json::num(r.iters as f64)),
                    ("mean_ns", Json::num(r.mean_ns)),
                    ("p50_ns", Json::num(r.p50_ns)),
                    ("p95_ns", Json::num(r.p95_ns)),
                    ("min_ns", Json::num(r.min_ns)),
                    ("units_per_iter", Json::num(r.units_per_iter)),
                ];
                if r.units_per_iter > 0.0 {
                    fields.push(("units_per_sec", Json::num(r.throughput())));
                }
                for (k, v) in &r.extras {
                    fields.push((k.as_str(), Json::num(*v)));
                }
                for (k, v) in &r.extras_str {
                    fields.push((k.as_str(), Json::str(v.clone())));
                }
                Json::obj(fields)
            })
            .collect();
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let mut fields = vec![
            ("suite", Json::str(self.suite.clone())),
            ("threads_available", Json::num(threads as f64)),
        ];
        for (k, v) in &self.meta {
            fields.push((k.as_str(), Json::str(v.clone())));
        }
        fields.push(("results", Json::Arr(results)));
        Json::obj(fields)
    }

    /// Write [`Bench::to_json`] to `path` (parent directories created).
    pub fn write_json(&self, path: &std::path::Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string_pretty())
    }

    /// Append all results to target/bench_results.csv.
    pub fn finish(&self) {
        let path = std::path::Path::new("target/bench_results.csv");
        let new_file = !path.exists();
        if let Some(dir) = path.parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        let mut body = String::new();
        if new_file {
            body.push_str("suite,name,iters,mean_ns,p50_ns,p95_ns,min_ns,units_per_iter\n");
        }
        for (name, r) in &self.results {
            body.push_str(&format!(
                "{},{},{},{:.1},{:.1},{:.1},{:.1},{}\n",
                self.suite, name, r.iters, r.mean_ns, r.p50_ns, r.p95_ns, r.min_ns,
                r.units_per_iter
            ));
        }
        use std::io::Write;
        if let Ok(mut f) = std::fs::OpenOptions::new().create(true).append(true).open(path) {
            let _ = f.write_all(body.as_bytes());
        }
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let opts = BenchOpts {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            min_iters: 3,
        };
        let mut b = Bench::with_opts("test", opts);
        let mut acc = 0u64;
        let r = b.bench("noop", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.iters >= 3);
        assert!(r.mean_ns >= 0.0);
        assert!(r.p95_ns >= r.p50_ns);
    }

    #[test]
    fn annotate_and_meta_land_in_json() {
        let opts = BenchOpts {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(5),
            min_iters: 1,
        };
        let mut b = Bench::with_opts("test", opts);
        b.bench_units("row", 10.0, || {
            black_box(1 + 1);
        });
        b.annotate("row", "speedup_vs_serial", 2.5);
        b.annotate("row", "dropped_nan", f64::NAN); // must be skipped
        b.annotate("missing", "ignored", 1.0); // unknown name: no-op
        b.annotate_str("row", "simd_effective", "sse2");
        b.annotate_str("missing", "ignored_str", "x"); // unknown name: no-op
        b.set_meta("simd", "scalar");
        b.set_meta("simd", "avx2"); // overwrite
        let json = b.to_json().to_string_pretty();
        assert!(json.contains("\"speedup_vs_serial\""));
        assert!(!json.contains("dropped_nan"));
        assert!(!json.contains("ignored"));
        assert!(json.contains("\"simd_effective\""));
        assert!(json.contains("\"sse2\""));
        assert!(json.contains("\"simd\""));
        assert!(json.contains("avx2"));
        assert!(!json.contains("scalar"));
    }

    #[test]
    fn record_ns_aggregates_external_times() {
        let opts = BenchOpts {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(1),
            min_iters: 1,
        };
        let mut b = Bench::with_opts("test", opts);
        let times: Vec<f64> = (1..=100).map(|i| i as f64 * 1000.0).collect();
        let r = b.record_ns("open_loop", &times, 1.0);
        assert_eq!(r.iters, 100);
        assert_eq!(r.min_ns, 1000.0);
        assert!(r.p50_ns > 40_000.0 && r.p50_ns < 60_000.0);
        b.annotate("open_loop", "p99_ms", 0.099);
        assert!(b.to_json().to_string().contains("\"p99_ms\""));
        // Empty input must stay JSON-safe (no NaN), not panic.
        let r = b.record_ns("empty", &[], 0.0);
        assert_eq!(r.iters, 0);
        assert_eq!(r.mean_ns, 0.0);
        assert!(!b.to_json().to_string().contains("NaN"));
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }
}
