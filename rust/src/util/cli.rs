//! Tiny CLI argument parser (offline substrate — no clap).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Subcommand dispatch lives in `main.rs`; this handles the flag
//! soup with typed accessors and an auto-generated usage string.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    seen_bool: Vec<String>,
}

impl Args {
    pub fn parse(raw: &[String]) -> Args {
        let mut a = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let tok = &raw[i];
            if let Some(stripped) = tok.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    a.flags.insert(stripped[..eq].to_string(), stripped[eq + 1..].to_string());
                } else if i + 1 < raw.len() && !raw[i + 1].starts_with("--") {
                    a.flags.insert(stripped.to_string(), raw[i + 1].clone());
                    i += 1;
                } else {
                    a.flags.insert(stripped.to_string(), "true".to_string());
                    a.seen_bool.push(stripped.to_string());
                }
            } else {
                a.positional.push(tok.clone());
            }
            i += 1;
        }
        a
    }

    pub fn from_env() -> Args {
        let raw: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&raw)
    }

    pub fn str(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, key: &str) -> Option<String> {
        self.flags.get(key).cloned()
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.flags
            .get(key)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1") | Some("yes"))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(toks: &[&str]) -> Args {
        Args::parse(&toks.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    }

    #[test]
    fn key_value_forms() {
        let a = parse(&["--model", "resnet20", "--bits=2", "train"]);
        assert_eq!(a.str("model", ""), "resnet20");
        assert_eq!(a.usize("bits", 0), 2);
        assert_eq!(a.positional, vec!["train"]);
    }

    #[test]
    fn bool_flags() {
        let a = parse(&["--quick", "--out", "dir"]);
        assert!(a.flag("quick"));
        assert!(!a.flag("missing"));
        assert_eq!(a.str("out", ""), "dir");
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.f64("lr", 0.01), 0.01);
        assert_eq!(a.str("x", "d"), "d");
        assert!(a.opt_str("x").is_none());
    }

    #[test]
    fn trailing_bool_flag() {
        let a = parse(&["--verbose"]);
        assert!(a.flag("verbose"));
    }
}
