//! Minimal JSON parser/serializer (offline substrate — no serde_json in the
//! vendored crate universe).
//!
//! Supports the full JSON grammar needed by `artifacts/manifest.json`,
//! checkpoints metadata, experiment configs and the `serve::net` wire
//! protocol: objects, arrays, strings with escapes, numbers (f64/i64),
//! booleans, null. Errors carry byte offsets for debuggability.
//!
//! The parser is safe on adversarial input: nesting is bounded by
//! [`MAX_DEPTH`] (a recursive-descent parser without a depth limit is a
//! stack-overflow primitive — `"[[[[…"` at a few hundred thousand bytes
//! would otherwise crash a network-facing replica), and every malformed
//! byte sequence yields a [`JsonError`], never a panic.

use std::collections::BTreeMap;
use std::fmt;

/// Maximum container nesting the parser accepts. Deep enough for every
/// legitimate document in the repo (manifests nest < 10 levels; wire
/// requests < 3) while bounding recursion on hostile input.
pub const MAX_DEPTH: usize = 128;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0, depth: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ----------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: `obj.str_at("file")?` with an error naming the key.
    pub fn str_at(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing string field {key:?}"))
    }

    pub fn usize_at(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing numeric field {key:?}"))
    }

    pub fn f64_at(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing numeric field {key:?}"))
    }

    pub fn arr_at(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing array field {key:?}"))
    }

    // -- construction helpers ------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
    }

    pub fn arr_f32(v: &[f32]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    // -- serialization --------------------------------------------------------
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                        if pretty {
                            out.push(' ');
                        }
                    }
                    e.write(out, indent, pretty);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&"  ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.nested(Self::object),
            Some(b'[') => self.nested(Self::array),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    /// Run a container parser one nesting level deeper, bounding the
    /// recursion at [`MAX_DEPTH`] so adversarial `[[[[…` input yields an
    /// error instead of overflowing the stack.
    fn nested(
        &mut self,
        f: fn(&mut Parser<'a>) -> Result<Json, JsonError>,
    ) -> Result<Json, JsonError> {
        if self.depth >= MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        self.depth += 1;
        let v = f(self);
        self.depth -= 1;
        v
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map_or(false, |c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c if c < 0x80 => {
                    // ASCII fast path: consume a contiguous run in one go
                    // (per-char UTF-8 validation here was O(n^2) on large
                    // manifests — see EXPERIMENTS.md §Perf L3).
                    let start = self.i;
                    while self
                        .peek()
                        .map_or(false, |b| b < 0x80 && b != b'"' && b != b'\\')
                    {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i]).unwrap());
                }
                _ => {
                    // non-ASCII: decode one codepoint (up to 4 bytes)
                    let end = (self.i + 4).min(self.b.len());
                    let rest = &self.b[self.i..end];
                    let valid = match std::str::from_utf8(rest) {
                        Ok(s) => s,
                        Err(e) if e.valid_up_to() > 0 => {
                            std::str::from_utf8(&rest[..e.valid_up_to()]).unwrap()
                        }
                        Err(_) => return Err(self.err("invalid utf8")),
                    };
                    let ch = valid.chars().next().ok_or_else(|| self.err("invalid utf8"))?;
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "x\ny", "d": null}, "e": true}"#;
        let v = Json::parse(src).unwrap();
        let again = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 42, "s": "hi", "a": [1,2]}"#).unwrap();
        assert_eq!(v.usize_at("n").unwrap(), 42);
        assert_eq!(v.str_at("s").unwrap(), "hi");
        assert_eq!(v.arr_at("a").unwrap().len(), 2);
        assert!(v.str_at("missing").is_err());
    }

    #[test]
    fn escapes() {
        let v = Json::parse(r#""aA\t\"b\"""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "aA\t\"b\"");
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo ☃");
    }

    #[test]
    fn depth_limit_accepts_max_and_rejects_beyond() {
        let at = format!("{}{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(Json::parse(&at).is_ok());
        let over = format!("{}{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        let e = Json::parse(&over).unwrap_err();
        assert!(e.msg.contains("nesting"), "{e}");
        // A hostile megabyte of brackets errors fast instead of
        // overflowing the recursive-descent stack.
        assert!(Json::parse(&"[".repeat(1_000_000)).is_err());
    }

    #[test]
    fn u64_accessor_rejects_fractions_and_negatives() {
        assert_eq!(Json::parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(Json::parse("0").unwrap().as_u64(), Some(0));
        assert_eq!(Json::parse("-1").unwrap().as_u64(), None);
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
        assert_eq!(Json::parse("\"7\"").unwrap().as_u64(), None);
    }
}
