//! Offline substrates: JSON, RNG, stats, CLI parsing, table rendering and a
//! micro-benchmark harness. The vendored crate universe contains only `xla`
//! and `anyhow`, so everything else a framework normally pulls from crates.io
//! is implemented (and unit-tested) here.

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
