//! Offline substrates: JSON, RNG, stats, CLI parsing, table rendering and a
//! micro-benchmark harness. The vendored crate universe contains only `xla`
//! and `anyhow`, so everything else a framework normally pulls from crates.io
//! is implemented (and unit-tested) here.

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;

/// One shared truthy-env-flag rule for the runtime knobs
/// (`LSQNET_FORCE_SCALAR`, `LSQNET_FUSED_UNPACK`, …): set and not `"0"`.
/// Call sites that need per-process stability cache the result in a
/// `OnceLock` — this helper just owns the parsing rule so knobs can't
/// drift apart.
pub fn env_truthy(name: &str) -> bool {
    std::env::var(name)
        .map(|v| {
            let v = v.trim();
            !v.is_empty() && v != "0"
        })
        .unwrap_or(false)
}
