//! Deterministic PCG32 RNG + distributions (offline substrate — no `rand`).
//!
//! Used by the synthetic-data generator, augmentation, shuffling and the
//! in-repo property-testing harness. Determinism across runs is part of the
//! experiment contract: every repro table records its seed.

/// PCG-XSH-RR 64/32 (O'Neill 2014).
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u32();
            let m = (x as u64) * (n as u64);
            let l = m as u32;
            if l >= n {
                return (m >> 32) as u32;
            }
            // rejection zone for small l
            let t = n.wrapping_neg() % n;
            if l >= t {
                return (m >> 32) as u32;
            }
        }
    }

    pub fn bool(&mut self, p: f32) -> bool {
        self.uniform() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-9 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Derive an independent stream (e.g. per worker / per epoch).
    pub fn fork(&mut self, tag: u64) -> Pcg32 {
        Pcg32::new(self.next_u64() ^ tag, tag.wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Pcg32::seeded(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_unbiased_small() {
        let mut r = Pcg32::seeded(3);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(11);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
