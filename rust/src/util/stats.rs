//! Small numerically-careful statistics helpers used by metrics, the bench
//! harness and the analysis modules.

/// Online mean/variance (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// p-th percentile (0..=100) by sorting a copy. Fine for bench-sized data.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (v[hi] - v[lo]) * (rank - lo as f64)
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// l2 norm of an f32 slice in f64 accumulation.
pub fn l2_norm(xs: &[f32]) -> f64 {
    xs.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

pub fn mean_abs(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().map(|x| x.abs() as f64).sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.0];
        let mut w = Welford::new();
        for x in xs {
            w.push(x);
        }
        assert!((w.mean() - 6.2).abs() < 1e-12);
        let naive_var = xs.iter().map(|x| (x - 6.2) * (x - 6.2)).sum::<f64>() / 4.0;
        assert!((w.var() - naive_var).abs() < 1e-9);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 16.0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert!((percentile(&xs, 50.0) - 50.5).abs() < 1e-9);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
    }

    #[test]
    fn norms() {
        assert!((l2_norm(&[3.0, 4.0]) - 5.0).abs() < 1e-9);
        assert!((mean_abs(&[-2.0, 2.0]) - 2.0).abs() < 1e-9);
    }
}
