//! ASCII table renderer for the repro harness: every paper table/figure is
//! printed as aligned `paper-vs-measured` rows plus a CSV alongside.

#[derive(Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            (0..ncol)
                .map(|i| format!(" {:<width$} ", cells[i], width = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format an accuracy as the paper does (one decimal), or "-" for absent.
pub fn acc(v: Option<f64>) -> String {
    match v {
        Some(v) => format!("{v:.1}"),
        None => "-".to_string(),
    }
}

pub fn f3(v: f64) -> String {
    format!("{v:.3}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("t", &["a", "bbbb"]);
        t.row(vec!["xx".into(), "y".into()]);
        let s = t.render();
        assert!(s.contains("== t =="));
        assert!(s.contains("xx"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[1].len(), lines[3].len());
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("t", &["a"]);
        t.row(vec!["x,y".into()]);
        assert!(t.to_csv().contains("\"x,y\""));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["only one".into()]);
    }
}
