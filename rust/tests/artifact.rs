//! `.lsqa` artifact tests: pack → load → bind round-trip parity (bitwise
//! logits vs the manifest path, with the panel-build counter proving the
//! artifact bind constructs zero panels), the corruption battery (every
//! way the bytes can be wrong surfaces as a typed `ArtifactError`, never
//! a panic or a silent fallback), and the registry-level refusals. All
//! native — the synthetic fixture provides the source manifest + params.

mod common;

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use lsqnet::runtime::artifact::writer::default_levels;
use lsqnet::runtime::kernels::panel_build_count;
use lsqnet::runtime::native::fixture::{write_synthetic_family, FixtureSpec};
use lsqnet::runtime::{
    pack_family, ArtifactError, Backend as _, BackendSpec, LoadedArtifact, Manifest, NativeEngine,
    PrepareOptions,
};
use lsqnet::serve::{ModelRegistry, VariantOptions};
use lsqnet::tensor::Tensor;

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("lsq_artifact_{tag}_{}", std::process::id()))
}

/// Synthesize a `cnn_small` fixture family at `bits`, pack it, and return
/// `(dir, family, artifact_path, params)`.
fn pack_fixture(tag: &str, bits: u32) -> (PathBuf, String, PathBuf, Vec<Tensor>) {
    let dir = tmp_dir(tag);
    std::fs::remove_dir_all(&dir).ok();
    let spec = FixtureSpec { image: 8, channels: 3, num_classes: 6, batch: 4, seed: 21 };
    let fam = write_synthetic_family(&dir, "cnn_small", bits, spec).unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let params = manifest.load_initial_params(&fam).unwrap();
    let out = dir.join(format!("{fam}.lsqa"));
    pack_family(&manifest, &fam, &params, &out, &default_levels()).unwrap();
    (dir, fam, out, params)
}

fn image(seed: usize, len: usize) -> Vec<f32> {
    (0..len).map(|j| ((seed * 31 + j * 7) % 13) as f32 / 13.0 - 0.5).collect()
}

/// Write a copy of `src` with byte `off` XORed by `mask`.
fn flip_byte(src: &Path, off: usize, mask: u8) -> PathBuf {
    let mut bytes = std::fs::read(src).unwrap();
    bytes[off] ^= mask;
    let out = src.with_extension(format!("flip{off}.lsqa"));
    std::fs::write(&out, &bytes).unwrap();
    out
}

/// The tentpole round trip at every serving precision: the artifact bind
/// must produce bitwise-identical logits to the manifest bind of the same
/// params, perform **zero** panel constructions (the borrowed-arena
/// path), and report identical storage accounting.
#[test]
fn artifact_bind_is_bitwise_equal_and_builds_zero_panels() {
    for bits in [2u32, 4, 8] {
        let (dir, fam, out, params) = pack_fixture(&format!("parity{bits}"), bits);
        let image_len = 8 * 8 * 3;

        // Manifest path: quantize + pack + panelize at bind time.
        let mut cold = NativeEngine::new(&dir).unwrap();
        let before_cold = panel_build_count();
        cold.prepare_infer(&fam, &params, &PrepareOptions::new()).unwrap();
        let cold_builds = panel_build_count() - before_cold;
        assert!(cold_builds > 0, "bits={bits}: manifest bind should build panels");

        // Artifact path: borrow prebuilt panels from the shared arena.
        let art = Arc::new(LoadedArtifact::load(&out).unwrap());
        assert_eq!(art.family(), fam);
        assert!(art.bound_level().is_some(), "default levels always include a usable rung");
        let mut warm = NativeEngine::from_artifact(Arc::clone(&art));
        let before_warm = panel_build_count();
        warm.prepare_infer(&fam, &[], &PrepareOptions::new()).unwrap();
        let warm_builds = panel_build_count() - before_warm;
        assert_eq!(warm_builds, 0, "bits={bits}: artifact bind must build zero panels");

        // Bitwise logits parity, several batches.
        for i in 0..4usize {
            let x = image(i, image_len);
            let a = cold.infer(&x).unwrap();
            let b = warm.infer(&x).unwrap();
            assert_eq!(a.len(), b.len());
            for (j, (va, vb)) in a.iter().zip(&b).enumerate() {
                assert_eq!(
                    va.to_bits(),
                    vb.to_bits(),
                    "bits={bits} batch {i} logit {j}: manifest {va} != artifact {vb}"
                );
            }
        }

        // Storage accounting must not drift between the two bind paths.
        let (mc, ma) = (cold.model().unwrap(), warm.model().unwrap());
        assert_eq!(mc.packed_bytes, ma.packed_bytes, "bits={bits}: packed accounting");
        assert_eq!(mc.panel_bytes, ma.panel_bytes, "bits={bits}: panel accounting");
        assert!(ma.panel_bytes > 0, "bits={bits}: panelized bind reports resident panels");

        std::fs::remove_dir_all(&dir).ok();
    }
}

/// A packed-only artifact (no PANELS sections) still binds and still
/// matches the manifest path bitwise — through the counted fallback
/// panel build, which is the point: fallback is *visible* in the counter.
#[test]
fn packed_only_artifact_falls_back_to_counted_panel_build() {
    let dir = tmp_dir("fallback");
    std::fs::remove_dir_all(&dir).ok();
    let spec = FixtureSpec { image: 8, channels: 3, num_classes: 6, batch: 4, seed: 21 };
    let fam = write_synthetic_family(&dir, "cnn_small", 4, spec).unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let params = manifest.load_initial_params(&fam).unwrap();
    let out = dir.join(format!("{fam}.lsqa"));
    pack_family(&manifest, &fam, &params, &out, &[]).unwrap();

    let art = Arc::new(LoadedArtifact::load(&out).unwrap());
    assert!(art.bound_level().is_none(), "no panels sections were written");
    let mut warm = NativeEngine::from_artifact(Arc::clone(&art));
    let before = panel_build_count();
    warm.prepare_infer(&fam, &[], &PrepareOptions::new()).unwrap();
    assert!(
        panel_build_count() > before,
        "fallback must go through the counted panel build"
    );

    let mut cold = NativeEngine::new(&dir).unwrap();
    cold.prepare_infer(&fam, &params, &PrepareOptions::new()).unwrap();
    let x = image(3, 8 * 8 * 3);
    let (a, b) = (cold.infer(&x).unwrap(), warm.infer(&x).unwrap());
    for (va, vb) in a.iter().zip(&b) {
        assert_eq!(va.to_bits(), vb.to_bits());
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Corruption battery, targeted: a bit flip inside any section body is a
/// `ChecksumMismatch` for that section; header-field edits produce their
/// specific typed errors; truncations produce `Truncated`.
#[test]
fn corrupted_artifacts_are_refused_with_typed_errors() {
    let (dir, _fam, out, _params) = pack_fixture("corrupt", 2);
    let clean = LoadedArtifact::load(&out).unwrap();

    // One flipped bit mid-body per section (META, TENSORS, PACKED, and
    // every PANELS level) → that section's checksum mismatch.
    for sec in clean.sections() {
        let bad = flip_byte(&out, sec.off + sec.len / 2, 0x10);
        match LoadedArtifact::load(&bad).map(|_| ()) {
            Err(ArtifactError::ChecksumMismatch { section }) => {
                assert!(section.starts_with("section "), "got {section:?}")
            }
            other => panic!("flipped section kind {}: got {other:?}", sec.kind),
        }
        std::fs::remove_file(&bad).ok();
    }

    // Magic, version, endianness, header checksum.
    let bad = flip_byte(&out, 0, 0xFF);
    assert!(matches!(LoadedArtifact::load(&bad), Err(ArtifactError::BadMagic)));
    std::fs::remove_file(&bad).ok();
    let bad = flip_byte(&out, 4, 0x40); // version 1 -> 65
    assert!(matches!(
        LoadedArtifact::load(&bad),
        Err(ArtifactError::UnsupportedVersion { got: 65, want: 1 })
    ));
    std::fs::remove_file(&bad).ok();
    {
        // Byte-swap the endian tag (0x1234 -> reads as 0x3412): the
        // written-on-a-big-endian-machine signature.
        let mut bytes = std::fs::read(&out).unwrap();
        bytes[6..8].copy_from_slice(&0x1234u16.to_be_bytes());
        let bad = out.with_extension("endian.lsqa");
        std::fs::write(&bad, &bytes).unwrap();
        assert!(matches!(LoadedArtifact::load(&bad), Err(ArtifactError::EndianMismatch)));
        std::fs::remove_file(&bad).ok();
    }
    let bad = flip_byte(&out, 40, 0x01); // reserved header byte — CRC'd
    assert!(matches!(
        LoadedArtifact::load(&bad),
        Err(ArtifactError::ChecksumMismatch { .. })
    ));
    std::fs::remove_file(&bad).ok();

    // Truncations: shorter than a header, and mid-body.
    let full = std::fs::read(&out).unwrap();
    for keep in [0usize, 17, 63, 64, full.len() / 2, full.len() - 1] {
        let bad = out.with_extension(format!("trunc{keep}.lsqa"));
        std::fs::write(&bad, &full[..keep]).unwrap();
        assert!(
            matches!(LoadedArtifact::load(&bad), Err(ArtifactError::Truncated { .. })),
            "keep={keep}"
        );
        std::fs::remove_file(&bad).ok();
    }

    std::fs::remove_dir_all(&dir).ok();
}

/// Corruption battery, randomized: arbitrary bit flips anywhere in the
/// file must never panic the loader — every outcome is `Ok` (flip landed
/// in dead padding) or a typed `ArtifactError`. The `forall` harness
/// turns a panic into a seed-reporting failure.
#[test]
fn random_bit_flips_never_panic_the_loader() {
    let (dir, _fam, out, _params) = pack_fixture("fuzz", 3);
    let bytes = std::fs::read(&out).unwrap();
    let case = std::sync::atomic::AtomicUsize::new(0);
    common::forall("loader survives random bit flips", 0xA11F_ACE5, 48, |rng| {
        let mut b = bytes.clone();
        for _ in 0..1 + rng.below(3) {
            let off = rng.below(b.len() as u32) as usize;
            b[off] ^= 1 << rng.below(8);
        }
        let n = case.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let bad = out.with_extension(format!("fuzz{n}.lsqa"));
        std::fs::write(&bad, &b).unwrap();
        // Must return, not panic; both Ok and typed Err are acceptable.
        let _ = LoadedArtifact::load(&bad);
        std::fs::remove_file(&bad).ok();
    });
    std::fs::remove_dir_all(&dir).ok();
}

/// Registry-level refusals: a corrupted artifact, a family-name mismatch,
/// and the artifact+checkpoint combination all fail `load` loudly; no
/// variant is left behind and nothing silently rebinds from a manifest.
#[test]
fn registry_refuses_bad_artifacts_loudly() {
    let (dir, fam, out, _params) = pack_fixture("refuse", 2);
    let clean = LoadedArtifact::load(&out).unwrap();
    let sec = clean.sections()[0];
    let bad = flip_byte(&out, sec.off + sec.len / 2, 0x08);

    let registry = ModelRegistry::open(BackendSpec::native(&dir));
    let opts = |path: &Path| VariantOptions {
        replicas: 1,
        artifact: Some(path.to_path_buf()),
        ..VariantOptions::default()
    };

    let err = registry.load(&fam, &opts(&bad)).unwrap_err();
    assert!(
        matches!(
            err.downcast_ref::<ArtifactError>(),
            Some(ArtifactError::ChecksumMismatch { .. })
        ),
        "corrupted artifact: {err:#}"
    );

    let err = registry.load("some_other_family", &opts(&out)).unwrap_err();
    match err.downcast_ref::<ArtifactError>() {
        Some(ArtifactError::FamilyMismatch { want, got }) => {
            assert_eq!(want, "some_other_family");
            assert_eq!(got, &fam);
        }
        other => panic!("family mismatch: got {other:?}"),
    }

    let err = registry
        .load(
            &fam,
            &VariantOptions {
                checkpoint: "ck.ckpt".to_string(),
                ..opts(&out)
            },
        )
        .unwrap_err();
    assert!(err.to_string().contains("mutually exclusive"), "{err:#}");

    assert!(registry.variants().is_empty(), "failed loads must not leave variants behind");
    registry.shutdown();
    std::fs::remove_file(&bad).ok();
    std::fs::remove_dir_all(&dir).ok();
}

/// Pure-artifact serving: a registry whose spec points at a directory
/// with **no manifest at all** serves a variant loaded from a `.lsqa`,
/// replicas bind with zero panel builds (shared arena), and the served
/// logits equal a direct artifact engine's.
#[test]
fn registry_serves_from_artifact_without_a_manifest() {
    let (dir, fam, out, _params) = pack_fixture("serve", 4);
    let image_len = 8 * 8 * 3;

    // Reference logits from a direct artifact engine.
    let art = Arc::new(LoadedArtifact::load(&out).unwrap());
    let mut direct = NativeEngine::from_artifact(Arc::clone(&art));
    direct.prepare_infer(&fam, &[], &PrepareOptions::new()).unwrap();
    let want: Vec<Vec<f32>> = (0..6).map(|i| direct.infer(&image(i, image_len)).unwrap()).collect();

    // Spec directory is empty: only the artifact knows the model.
    let empty = tmp_dir("serve_empty");
    std::fs::remove_dir_all(&empty).ok();
    std::fs::create_dir_all(&empty).unwrap();
    let registry = ModelRegistry::open(BackendSpec::native(&empty));
    let before = panel_build_count();
    registry
        .load(
            &fam,
            &VariantOptions {
                replicas: 2,
                max_wait: Duration::from_millis(1),
                artifact: Some(out.clone()),
                ..VariantOptions::default()
            },
        )
        .unwrap();
    let session = registry.session(&fam).unwrap();
    for (i, w) in want.iter().enumerate() {
        let rep = session.infer(image(i, image_len)).unwrap();
        assert_eq!(&rep.logits, w, "request {i}");
    }
    // load()'s dry-run bind is Fused (no panels); both replicas borrow
    // from the arena — the counter must not have moved.
    assert_eq!(panel_build_count() - before, 0, "replicas must share the artifact arena");
    drop(session);
    registry.shutdown();
    std::fs::remove_dir_all(&empty).ok();
    std::fs::remove_dir_all(&dir).ok();
}

/// `inspect` smoke: the summary names the family, lists every section,
/// and marks the panels rung this host binds.
#[test]
fn inspect_summarizes_sections_and_bound_level() {
    let (dir, fam, out, _params) = pack_fixture("inspect", 2);
    let art = LoadedArtifact::load(&out).unwrap();
    let text = art.inspect();
    assert!(text.contains(&fam), "{text}");
    for kind in ["meta", "tensors", "packed", "panels"] {
        assert!(text.contains(kind), "missing {kind} in:\n{text}");
    }
    assert!(text.contains("<- binds on this host"), "{text}");
    std::fs::remove_dir_all(&dir).ok();
}
