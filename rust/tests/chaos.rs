//! Chaos acceptance tests (DESIGN.md §Fault-model): a seeded [`FaultPlan`]
//! kills replicas and sabotages connections mid-flood, and the stack must
//! (a) resolve every offered request — a reply, a typed error, or a clean
//! client-side connection error, never a hang; (b) converge back to the
//! full replica count once the schedule has played out; and (c) replay
//! bit-for-bit: the same seed reproduces the identical fault schedule,
//! the identical fired occurrence-index sets, and the identical
//! supervision stats (`replica_failures`/`replica_restarts`).
//!
//! Determinism discipline: which *wall-clock request* lands on a firing
//! occurrence index is scheduling-dependent, so nothing here asserts an
//! ok/error split. The floods loop until `FaultPlan::all_fired()` (with a
//! wall-clock cap), which pins the fired sets to the full planned sets —
//! the replay comparison is then exact, not statistical. `ci.sh` runs
//! this file twice for the same reason: each test already replays its
//! scenario in-process, and the double run replays it across processes.

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use lsqnet::runtime::native::fixture::{write_synthetic_family, FixtureSpec};
use lsqnet::runtime::BackendSpec;
use lsqnet::serve::net::{NetClient, NetServer, RetryPolicy};
use lsqnet::serve::{FaultPlan, FaultSpec, ModelRegistry, RestartPolicy, VariantOptions};

const IMAGE_LEN: usize = 8 * 8 * 3;
const REPLICAS: usize = 3;

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("lsq_chaos_{tag}_{}", std::process::id()))
}

fn image(seed: usize) -> Vec<f32> {
    (0..IMAGE_LEN).map(|j| ((seed * 31 + j * 7) % 13) as f32 / 13.0 - 0.5).collect()
}

/// The acceptance scenario: ≥3 replica kills plus ≥2 connection faults of
/// every net kind, over a horizon small enough that a bounded flood plays
/// the whole schedule out. Fault delays are kept tiny — the *paths* are
/// what's under test, not the latencies.
fn chaos_spec(seed: u64) -> FaultSpec {
    FaultSpec {
        seed,
        horizon: 48,
        replica_panics: 4,
        slow_execs: 3,
        slow_exec: Duration::from_millis(5),
        stalled_reads: 2,
        read_stall: Duration::from_millis(5),
        dropped_conns: 2,
        corrupt_frames: 2,
        truncated_writes: 2,
        ..FaultSpec::default()
    }
}

/// What one chaos run leaves behind. Only schedule-deterministic facts —
/// never the ok/error split, which depends on thread interleaving.
#[derive(Debug, PartialEq, Eq)]
struct Outcome {
    schedule: String,
    fired: BTreeMap<&'static str, Vec<u64>>,
    replica_failures: u64,
    replica_restarts: u64,
    live_replicas: usize,
    healthy: bool,
}

/// One full chaos run: registry + net server share one seeded plan, a
/// retrying client floods until every planned fault has fired, then the
/// run waits for the supervisor to restore full capacity.
fn chaos_run(seed: u64, run: usize) -> Outcome {
    let dir = tmp_dir(&format!("{seed}_{run}"));
    std::fs::remove_dir_all(&dir).ok();
    let spec = FixtureSpec { image: 8, channels: 3, num_classes: 6, batch: 4, seed: 33 };
    let family = write_synthetic_family(&dir, "cnn_small", 2, spec).unwrap();

    let plan = Arc::new(FaultPlan::new(&chaos_spec(seed)));
    let registry = Arc::new(ModelRegistry::open(BackendSpec::native(&dir)));
    registry
        .load(
            &family,
            &VariantOptions {
                replicas: REPLICAS,
                max_wait: Duration::from_millis(0),
                queue_depth: 64,
                fault: Some(Arc::clone(&plan)),
                restarts: RestartPolicy {
                    budget: 16, // well above the 4 planned panics: stay healthy
                    window: Duration::from_secs(60),
                    backoff: Duration::from_millis(1),
                    backoff_cap: Duration::from_millis(4),
                    jitter_seed: 0,
                },
                ..VariantOptions::default()
            },
        )
        .unwrap();
    let server =
        NetServer::start_faulted(Arc::clone(&registry), None, "127.0.0.1:0", Some(Arc::clone(&plan)))
            .unwrap();
    let addr = server.local_addr();

    // Flood in rounds of synchronous infers until the whole schedule has
    // played out. Retries are armed, so dropped/corrupted/truncated
    // connections are survived transparently; whatever still errors out
    // (e.g. the budget of 5 attempts exhausted mid-storm) is a *resolved*
    // outcome — the conservation law is "every offered request returns",
    // enforced here simply by the loop making progress under the cap.
    let (mut ok, mut errs) = (0usize, 0usize);
    let t0 = Instant::now();
    let cap = Duration::from_secs(120);
    let mut round = 0usize;
    while !plan.all_fired() {
        assert!(
            t0.elapsed() < cap,
            "chaos flood did not play out the schedule within {cap:?}; \
             fired {:?} of planned {}",
            plan.fired(),
            plan.schedule()
        );
        round += 1;
        let mut client = match NetClient::connect(addr) {
            Ok(c) => c,
            Err(_) => continue, // accept backlog mid-storm: next round retries
        };
        client.set_retry(Some(RetryPolicy {
            max_attempts: 5,
            backoff: Duration::from_millis(1),
            backoff_cap: Duration::from_millis(8),
            seed,
        }));
        for i in 0..16usize {
            match client.infer(&family, &image(round * 100 + i)) {
                Ok(rep) => {
                    assert_eq!(rep.logits.len(), 6);
                    assert!(rep.logits.iter().all(|v| v.is_finite()));
                    ok += 1;
                }
                Err(_) => errs += 1,
            }
        }
    }
    assert!(ok > 0, "the stack served nothing at all through the storm (errs={errs})");

    // Convergence: the supervisor restores every panicked replica. Poll
    // the restart counter too — it is bumped adjacent to (not atomically
    // with) the respawned thread's liveness increment.
    let t1 = Instant::now();
    while registry.live_replicas(&family).unwrap() < REPLICAS
        || registry.stats(&family).unwrap().replica_restarts < 4
    {
        assert!(
            t1.elapsed() < Duration::from_secs(10),
            "registry never converged back to {REPLICAS} replicas; \
             live={} stats={:?}",
            registry.live_replicas(&family).unwrap(),
            registry.stats(&family).unwrap()
        );
        std::thread::sleep(Duration::from_millis(5));
    }

    // Post-storm liveness on a fresh, fault-free connection (every planned
    // index has fired; later occurrences never fire).
    let mut client = NetClient::connect(addr).unwrap();
    assert_eq!(client.infer(&family, &image(424_242)).unwrap().logits.len(), 6);
    drop(client);

    let stats = registry.stats(&family).unwrap();
    let outcome = Outcome {
        schedule: plan.schedule(),
        fired: plan.fired(),
        replica_failures: stats.replica_failures,
        replica_restarts: stats.replica_restarts,
        live_replicas: registry.live_replicas(&family).unwrap(),
        healthy: registry.healthy(&family).unwrap(),
    };
    server.stop();
    if let Ok(r) = Arc::try_unwrap(registry) {
        r.shutdown();
    }
    std::fs::remove_dir_all(&dir).ok();
    outcome
}

/// The headline acceptance test: at two fixed seeds, the full-stack chaos
/// scenario (4 replica kills, 2 each of stalled/dropped/corrupted/
/// truncated connection faults) resolves every offered request, converges
/// back to full replica count, and replays identically in-process.
#[test]
fn chaos_flood_recovers_and_replays_bit_for_bit() {
    for seed in [0xC0FFEEu64, 41] {
        let a = chaos_run(seed, 0);
        // The supervision ledger is exact, not approximate: every planned
        // panic killed a replica, every kill was restarted, capacity is
        // whole again and the variant never went unhealthy.
        assert_eq!(a.replica_failures, 4, "seed {seed}: {a:?}");
        assert_eq!(a.replica_restarts, 4, "seed {seed}: {a:?}");
        assert_eq!(a.live_replicas, REPLICAS, "seed {seed}: {a:?}");
        assert!(a.healthy, "seed {seed}: variant must stay healthy under budget: {a:?}");
        // Every site fully fired (the flood loops until `all_fired`), so
        // the fired maps equal the planned sets — and must replay.
        assert_eq!(a.fired.values().map(Vec::len).sum::<usize>() as u64, 15);

        let b = chaos_run(seed, 1);
        assert_eq!(a, b, "seed {seed}: a chaos run must replay bit-for-bit");
    }
}

/// Replica-domain replay without the net stack: the same seed drives the
/// same panic/slow schedule straight through `Session::infer`, and the
/// supervision stats and fired sets replay exactly. Isolates the registry
/// half of the determinism argument from socket nondeterminism.
#[test]
fn replica_fault_schedule_replays_through_the_registry_alone() {
    fn run(seed: u64, run: usize) -> Outcome {
        let dir = tmp_dir(&format!("reg_{seed}_{run}"));
        std::fs::remove_dir_all(&dir).ok();
        let spec = FixtureSpec { image: 8, channels: 3, num_classes: 4, batch: 4, seed: 17 };
        let family = write_synthetic_family(&dir, "mlp", 2, spec).unwrap();
        let plan = Arc::new(FaultPlan::new(&FaultSpec {
            seed,
            horizon: 32,
            replica_panics: 3,
            slow_execs: 2,
            slow_exec: Duration::from_millis(2),
            ..FaultSpec::default()
        }));
        let registry = Arc::new(ModelRegistry::open(BackendSpec::native(&dir)));
        registry
            .load(
                &family,
                &VariantOptions {
                    replicas: 2,
                    max_wait: Duration::from_millis(0),
                    queue_depth: 32,
                    fault: Some(Arc::clone(&plan)),
                    restarts: RestartPolicy {
                        budget: 8,
                        window: Duration::from_secs(60),
                        backoff: Duration::from_millis(1),
                        backoff_cap: Duration::from_millis(4),
                        jitter_seed: 0,
                    },
                    ..VariantOptions::default()
                },
            )
            .unwrap();
        let session = registry.session(&family).unwrap();
        let t0 = Instant::now();
        let mut i = 0usize;
        while !plan.all_fired() {
            assert!(
                t0.elapsed() < Duration::from_secs(60),
                "registry flood never played out the schedule: fired {:?}",
                plan.fired()
            );
            // Synchronous single-request batches: each either replies or
            // carries the typed error of a replica dying mid-batch. Both
            // are "answered exactly once"; a hang would trip the cap.
            let _ = session.infer(image(i));
            i += 1;
        }
        let t1 = Instant::now();
        while registry.live_replicas(&family).unwrap() < 2
            || registry.stats(&family).unwrap().replica_restarts < 3
        {
            assert!(t1.elapsed() < Duration::from_secs(10), "no reconvergence to 2 replicas");
            std::thread::sleep(Duration::from_millis(5));
        }
        let stats = registry.stats(&family).unwrap();
        // The synchronous driver makes the ledger checkable in full:
        // every infer call returned, so everything accepted is answered.
        assert_eq!(stats.answered(), i as u64, "accepted ⇒ answered exactly once");
        let outcome = Outcome {
            schedule: plan.schedule(),
            fired: plan.fired(),
            replica_failures: stats.replica_failures,
            replica_restarts: stats.replica_restarts,
            live_replicas: registry.live_replicas(&family).unwrap(),
            healthy: registry.healthy(&family).unwrap(),
        };
        if let Ok(r) = Arc::try_unwrap(registry) {
            r.shutdown();
        }
        std::fs::remove_dir_all(&dir).ok();
        outcome
    }

    let a = run(0x5eed_cafe, 0);
    assert_eq!(a.replica_failures, 3, "{a:?}");
    assert_eq!(a.replica_restarts, 3, "{a:?}");
    assert!(a.healthy, "{a:?}");
    let b = run(0x5eed_cafe, 1);
    assert_eq!(a, b, "registry-only chaos must replay bit-for-bit");
}
