//! Helpers shared by the integration-test binaries (pulled in via
//! `mod common;` — files in `tests/` subdirectories are not test binaries
//! themselves).

use lsqnet::util::rng::Pcg32;

/// Run `f` over `cases` seeded cases starting at `base_seed`, reporting
/// the failing case seed for replay — the in-repo property-test
/// mini-framework (the vendored crate universe has no proptest).
pub fn forall(name: &str, base_seed: u64, cases: u64, mut f: impl FnMut(&mut Pcg32)) {
    for seed in 0..cases {
        let mut rng = Pcg32::seeded(base_seed + seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property {name:?} failed at case seed {seed}: {e:?}");
        }
    }
}
