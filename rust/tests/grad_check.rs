//! Finite-difference gradient checks for the native LSQ backward pass.
//!
//! Strategy (see `train::native::grad`): the STE quantizer's hand-written
//! backward (Eq. 5 data mask, Eq. 3 step gradient) is exactly the
//! derivative of a *surrogate* `h(v, s) = s·(clip(v/s) + c)` with the
//! rounding offset `c` frozen at the evaluation point. Central differences
//! of the surrogate are therefore a legitimate f64 reference wherever the
//! stencil stays inside one quantization cell — which `safe_gradcheck_point`
//! guarantees. The full-precision network path (no rounding anywhere) is
//! additionally checked end-to-end against central differences of the real
//! training loss, covering the GEMM transposes, the im2col adjoint, batch
//! norm, pooling and the softmax head.

use lsqnet::quant::lsq::{grad_scale, lsq_vjp, qrange};
use lsqnet::runtime::kernels::Workspace;
use lsqnet::runtime::native::fixture::{write_synthetic_family, FixtureSpec};
use lsqnet::runtime::Manifest;
use lsqnet::train::native::grad::{central_diff, lsq_surrogate_f64, safe_gradcheck_point};
use lsqnet::train::native::NativeTrainModel;
use lsqnet::util::rng::Pcg32;

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("lsq_gradcheck_{tag}_{}", std::process::id()))
}

fn rel_err(got: f64, want: f64) -> f64 {
    (got - want).abs() / want.abs().max(1e-6)
}

/// The satellite check: ∂L/∂v and ∂L/∂s of the LSQ quantizer against f64
/// central differences of the STE-consistent surrogate, at 2/3/4/8 bits,
/// signed and unsigned, for both quantizer roles (weights: N = element
/// count; activations: N = trailing feature count). rel-err < 1e-2.
#[test]
fn lsq_vjp_matches_central_differences() {
    const MARGIN: f64 = 0.05;
    for bits in [2u32, 3, 4, 8] {
        for signed in [true, false] {
            let (qn, qp) = qrange(bits, signed);
            for (role, n_items) in [("weight", 96usize), ("activation", 16usize)] {
                let mut rng = Pcg32::seeded(2_000 + bits as u64 * 31 + signed as u64 * 7);
                let s = 0.17f32 + 0.05 * bits as f32;
                let n = 96usize;
                let v: Vec<f32> = (0..n)
                    .map(|_| {
                        if signed {
                            rng.normal() * 0.8
                        } else {
                            rng.normal().abs() * 0.8
                        }
                    })
                    .collect();
                let cot: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
                let g = grad_scale(n_items, qp);
                let (gv, gs) = lsq_vjp(&v, s, qn, qp, g, &cot);

                // ∂L/∂v per element (only where the frozen offset is valid)
                let mut checked = 0usize;
                for i in 0..n {
                    let (vi, si) = (v[i] as f64, s as f64);
                    if !safe_gradcheck_point(vi, si, qn, qp, MARGIN) {
                        continue;
                    }
                    let h = MARGIN * si / 8.0;
                    let num = cot[i] as f64
                        * central_diff(
                            |x| lsq_surrogate_f64(x, si, vi, si, qn, qp),
                            vi,
                            h,
                        );
                    let got = gv[i] as f64;
                    assert!(
                        rel_err(got, num) < 1e-2 || (got - num).abs() < 1e-5,
                        "bits={bits} signed={signed} role={role} dv[{i}]: {got} vs {num}"
                    );
                    checked += 1;
                }
                assert!(checked > n / 2, "too few safe points: {checked}/{n}");

                // ∂L/∂s: sum the numeric per-element terms over the safe
                // subset and compare against the analytic sum restricted
                // to the same subset (scaled by g).
                let mut num_ds = 0.0f64;
                let mut ana_ds = 0.0f64;
                for i in 0..n {
                    let (vi, si) = (v[i] as f64, s as f64);
                    if !safe_gradcheck_point(vi, si, qn, qp, MARGIN) {
                        continue;
                    }
                    let r = (vi / si).abs().max(1.0);
                    let h = MARGIN * si / (8.0 * r);
                    num_ds += cot[i] as f64
                        * central_diff(
                            |sx| lsq_surrogate_f64(vi, sx, vi, si, qn, qp),
                            si,
                            h,
                        );
                    ana_ds += cot[i] as f64
                        * lsqnet::quant::lsq::grad_s_term(v[i], s, qn, qp) as f64;
                }
                let num_ds = num_ds * g;
                let ana_ds = ana_ds * g;
                assert!(
                    rel_err(ana_ds, num_ds) < 1e-2,
                    "bits={bits} signed={signed} role={role} ds: {ana_ds} vs {num_ds}"
                );
                // and the full analytic reduction is finite + uses g
                assert!(gs.is_finite(), "bits={bits}");
            }
        }
    }
}

/// The Eq. 5 mask by name: the STE passes the cotangent untouched strictly
/// inside the clip range and zeroes it outside, at every width.
#[test]
fn ste_passes_inside_clip_range_and_zeroes_outside() {
    for bits in [2u32, 3, 4, 8] {
        for signed in [true, false] {
            let (qn, qp) = qrange(bits, signed);
            let s = 0.5f32;
            // strictly inside, exactly at both clips, far outside
            let inside = 0.5 * s * (qp.max(1) as f32 - 0.49);
            let v = [inside, -(qn as f32) * s - 1.0, (qp as f32) * s + 1.0];
            let cot = [0.7f32, 0.7, 0.7];
            let (gv, _) = lsq_vjp(&v, s, qn, qp, 1.0, &cot);
            assert_eq!(gv[0], 0.7, "bits={bits} signed={signed} inside");
            assert_eq!(gv[1], 0.0, "bits={bits} signed={signed} below");
            assert_eq!(gv[2], 0.0, "bits={bits} signed={signed} above");
        }
    }
}

/// Network-level check on the full-precision (q32) path, where the real
/// training loss is differentiable: `loss_and_grads` vs central
/// differences of the loss itself, for every parameter kind the backward
/// touches (conv/dense weights, biases, BN γ/β) across both tested archs.
#[test]
fn network_grads_match_central_differences_fp32() {
    for model in ["mlp", "cnn_small"] {
        let dir = tmp_dir(model);
        std::fs::remove_dir_all(&dir).ok();
        let spec = FixtureSpec { image: 8, channels: 3, num_classes: 5, batch: 2, seed: 31 };
        let family = write_synthetic_family(&dir, model, 32, spec).unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let fam = manifest.family(&family).unwrap().clone();
        let mut params = manifest.load_initial_params(&family).unwrap();
        let net = NativeTrainModel::build(&manifest, &family, "lsq", "full").unwrap();

        let rows = 2usize;
        let mut rng = Pcg32::seeded(77);
        let x: Vec<f32> = (0..rows * net.image_len()).map(|_| rng.normal()).collect();
        let y = vec![1i32, 3];

        let mut ws = Workspace::new();
        let out = net.loss_and_grads(&mut ws, &params, &x, &y, rows).unwrap();
        assert!(out.loss.is_finite());

        // Map grad slots back to parameter indices.
        let gidx_of: Vec<usize> = fam
            .grad_names
            .iter()
            .map(|n| fam.param_names.iter().position(|p| p == n).unwrap())
            .collect();

        // Directional check per tensor: perturb along the *normalized
        // analytic gradient* u = g/|g|; if the backward is correct, the
        // directional derivative dL/dt of L(θ + t·u) at t = 0 equals |g|.
        // This aggregates the whole tensor into one large-signal number,
        // which is what makes an f32 forward finite-differenceable.
        let mut checked = 0usize;
        for (gi, gname) in fam.grad_names.iter().enumerate() {
            let pi = gidx_of[gi];
            let g: Vec<f64> = out.grads[gi].f32s().unwrap().iter().map(|&v| v as f64).collect();
            let norm = g.iter().map(|v| v * v).sum::<f64>().sqrt();
            if norm < 1e-3 {
                continue; // too small for f32 finite differences
            }
            let u: Vec<f32> = g.iter().map(|&v| (v / norm) as f32).collect();
            let orig = params[pi].f32s().unwrap().to_vec();
            let mut loss_at = |t: f32| -> f64 {
                {
                    let p = params[pi].f32s_mut().unwrap();
                    for (pv, (&o, &uv)) in p.iter_mut().zip(orig.iter().zip(&u)) {
                        *pv = o + t * uv;
                    }
                }
                let l = net.loss_and_grads(&mut ws, &params, &x, &y, rows).unwrap().loss;
                let p = params[pi].f32s_mut().unwrap();
                p.copy_from_slice(&orig);
                l
            };
            let h = 0.02f32;
            let num = (8.0 * (loss_at(h) - loss_at(-h)) - (loss_at(2.0 * h) - loss_at(-2.0 * h)))
                / (12.0 * h as f64);
            assert!(
                rel_err(norm, num) < 1e-2,
                "{model} {gname}: |g| = {norm} vs directional derivative {num}"
            );
            checked += 1;
        }
        assert!(checked >= 3, "{model}: only {checked} gradient tensors were checkable");
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// Quantized-path plumbing check: the step-size gradients must scale by
/// exactly `g = 1/√(N·Qp)` relative to the unscaled mode — with N the
/// *weight count* for `sw` and the *trailing feature count* for `sa`
/// (mirroring `layers._quantize_pair`). Run on cnn_small so interior
/// layers carry real 2-bit quantizers.
#[test]
fn gscale_uses_weight_count_for_sw_and_feature_count_for_sa() {
    let dir = tmp_dir("gscale");
    std::fs::remove_dir_all(&dir).ok();
    let spec = FixtureSpec { image: 8, channels: 3, num_classes: 5, batch: 2, seed: 5 };
    let family = write_synthetic_family(&dir, "cnn_small", 2, spec).unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let fam = manifest.family(&family).unwrap().clone();
    let params = manifest.load_initial_params(&family).unwrap();

    let full = NativeTrainModel::build(&manifest, &family, "lsq", "full").unwrap();
    let one = NativeTrainModel::build(&manifest, &family, "lsq", "one").unwrap();

    let rows = 2usize;
    let mut rng = Pcg32::seeded(11);
    let x: Vec<f32> = (0..rows * full.image_len()).map(|_| rng.normal()).collect();
    let y = vec![0i32, 2];
    let mut ws = Workspace::new();
    let gf = full.loss_and_grads(&mut ws, &params, &x, &y, rows).unwrap().grads;
    let go = one.loss_and_grads(&mut ws, &params, &x, &y, rows).unwrap().grads;

    // conv2 is an interior layer: true 2-bit quantizers.
    let bits_of = |name: &str| fam.layer_meta.iter().find(|l| l.name == name).unwrap().bits;
    assert_eq!(bits_of("conv2"), 2);
    let slot = |n: &str| fam.grad_names.iter().position(|g| g == n).unwrap();
    let wlen = 3 * 3 * 16 * 32; // conv2 HWIO weight count

    let sw_full = gf[slot("conv2.sw")].f32s().unwrap()[0] as f64;
    let sw_one = go[slot("conv2.sw")].f32s().unwrap()[0] as f64;
    let (_, qp_w) = qrange(2, true);
    let want_w = 1.0 / ((wlen as f64) * qp_w as f64).sqrt();
    assert!(sw_one.abs() > 1e-12, "sw gradient vanished");
    assert!(
        rel_err(sw_full / sw_one, want_w) < 1e-3,
        "sw scale: {} vs {want_w}",
        sw_full / sw_one
    );

    let sa_full = gf[slot("conv2.sa")].f32s().unwrap()[0] as f64;
    let sa_one = go[slot("conv2.sa")].f32s().unwrap()[0] as f64;
    let (_, qp_a) = qrange(2, false); // conv2 input is post-ReLU: unsigned
    let want_a = 1.0 / (16.0 * qp_a as f64).sqrt(); // N = in_ch = 16
    assert!(sa_one.abs() > 1e-12, "sa gradient vanished");
    assert!(
        rel_err(sa_full / sa_one, want_a) < 1e-3,
        "sa scale: {} vs {want_a}",
        sa_full / sa_one
    );
    std::fs::remove_dir_all(&dir).ok();
}
