//! Integration tests over the real AOT artifacts (run `make artifacts` first).
//!
//! These exercise the full rust↔XLA boundary: manifest contract, execution,
//! cross-validation of the Pallas kernels against the pure-Rust quantizer,
//! the trainer loop, checkpoint round-trips, the fp32→quant fine-tune
//! mapping, the serve path and the sweep coordinator.

use std::path::PathBuf;

use lsqnet::config::ExperimentConfig;
use lsqnet::data::{Dataset, SynthSpec};
use lsqnet::runtime::Engine;
use lsqnet::tensor::Tensor;
use lsqnet::train::{TrainState, Trainer};

fn artifacts() -> PathBuf {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    assert!(
        p.join("manifest.json").exists(),
        "artifacts/manifest.json missing — run `make artifacts` first"
    );
    p
}

fn quick_cfg(bits: u32) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.model = "cnn_small".into();
    cfg.bits = bits;
    cfg.backend = "xla".into(); // this whole suite drives the AOT artifacts
    cfg.name = format!("it_q{bits}");
    cfg.out_dir = std::env::temp_dir()
        .join(format!("lsq_it_{}", std::process::id()))
        .to_string_lossy()
        .to_string();
    cfg.artifacts_dir = artifacts().to_string_lossy().to_string();
    cfg.data.train_size = 256;
    cfg.data.test_size = 64;
    cfg.train.epochs = 1;
    cfg.train.max_steps = 3;
    cfg
}

#[test]
fn manifest_contract_holds() {
    let engine = Engine::new(&artifacts()).unwrap();
    let m = engine.manifest();
    assert!(m.families.len() >= 2);
    for fam in m.families.values() {
        // params.bin loads and shapes line up
        let params = m.load_initial_params(&fam.name).unwrap();
        assert_eq!(params.len(), fam.param_names.len());
        for (name, t) in fam.param_names.iter().zip(&params) {
            assert_eq!(&t.shape, fam.shapes.get(name).unwrap(), "{name}");
        }
        // grad names are a subset of param names, states excluded
        for g in &fam.grad_names {
            assert!(fam.param_names.contains(g));
            assert_ne!(fam.roles.get(g).map(String::as_str), Some("state"));
        }
    }
    // every train artifact echoes params/moms in identical order
    for a in m.artifacts.values().filter(|a| a.kind.starts_with("train")) {
        let fam = m.family(a.family.as_deref().unwrap()).unwrap();
        let p = fam.param_names.len();
        let innames: Vec<&str> = a.inputs[..p].iter().map(|i| i.name.as_str()).collect();
        let outnames: Vec<&str> = a.outputs[..p].iter().map(|o| o.name.as_str()).collect();
        assert_eq!(innames, outnames, "{}", a.id);
        assert_eq!(a.inputs[a.inputs.len() - 2].kind, "lr");
        assert_eq!(a.inputs[a.inputs.len() - 1].kind, "wd");
    }
}

#[test]
fn fig2_artifact_matches_rust_quantizer_curves() {
    let engine = Engine::new(&artifacts()).unwrap();
    let c = lsqnet::analyze::curves::from_artifact(&engine, -1.0, 4.0).unwrap();
    let r = lsqnet::analyze::curves::from_rust(-1.0, 4.0, c.v.len());
    for i in 0..c.v.len() {
        assert!((c.vhat[i] - r.vhat[i]).abs() < 1e-5, "vhat at v={}", c.v[i]);
        assert!((c.ds_lsq[i] - r.ds_lsq[i]).abs() < 1e-5, "ds at v={}", c.v[i]);
        assert!((c.ds_qil[i] - r.ds_qil[i]).abs() < 1e-5);
        assert!((c.ds_pact[i] - r.ds_pact[i]).abs() < 1e-5);
    }
}

#[test]
fn qmm_artifact_matches_host_integer_math() {
    let engine = Engine::new(&artifacts()).unwrap();
    let id = engine
        .manifest()
        .artifacts
        .values()
        .find(|a| a.kind == "qmm")
        .unwrap()
        .id
        .clone();
    let exe = engine.load(&id).unwrap();
    let (m, k) = (exe.meta.inputs[0].shape[0], exe.meta.inputs[0].shape[1]);
    let n = exe.meta.inputs[1].shape[1];
    let mut rng = lsqnet::util::rng::Pcg32::seeded(3);
    let x: Vec<i32> = (0..m * k).map(|_| rng.below(7) as i32 - 3).collect();
    let w: Vec<i32> = (0..k * n).map(|_| rng.below(7) as i32 - 3).collect();
    let out = exe
        .run(&[
            Tensor::from_i32(&[m, k], x.clone()),
            Tensor::from_i32(&[k, n], w.clone()),
            Tensor::scalar_f32(0.25),
            Tensor::scalar_f32(0.5),
        ])
        .unwrap();
    let got = out[0].f32s().unwrap();
    for r in 0..m {
        for c in 0..n {
            let acc: i64 = (0..k).map(|i| x[r * k + i] as i64 * w[i * n + c] as i64).sum();
            let want = acc as f32 * 0.125;
            assert!(
                (got[r * n + c] - want).abs() < 1e-3,
                "({r},{c}): {} vs {want}",
                got[r * n + c]
            );
        }
    }
}

#[test]
fn trainer_reduces_loss_and_checkpoints_roundtrip() {
    let engine = Engine::new(&artifacts()).unwrap();
    let mut cfg = quick_cfg(2);
    cfg.train.epochs = 5; // 256 imgs / b64 = 4 steps per epoch
    cfg.train.max_steps = 10;
    cfg.train.lr = 0.05;
    cfg.data.noise = 0.4; // easier -> visible progress in 10 steps
    let mut tr = Trainer::new(&engine, cfg.clone()).unwrap();
    tr.verbose = false;
    let rep = tr.fit().unwrap();
    assert_eq!(rep.history.steps.len(), 10);
    // Learning signal: the best later loss beats the first-step loss.
    let first = rep.history.steps[0].loss;
    let best_later = rep.history.steps[3..]
        .iter()
        .map(|s| s.loss)
        .fold(f64::INFINITY, f64::min);
    assert!(best_later < first, "loss {first} -> best {best_later}");

    // checkpoint reload reproduces eval exactly
    let st = TrainState::load(engine.manifest(), &rep.checkpoint).unwrap();
    assert_eq!(st.step, 10);
    let mut cfg2 = cfg.clone();
    cfg2.init_from = rep.checkpoint.to_string_lossy().to_string();
    let mut tr2 = Trainer::new(&engine, cfg2).unwrap();
    let (l1, t1a, t5a) = tr.evaluate().unwrap();
    let (l2, t1b, t5b) = tr2.evaluate().unwrap();
    assert!((l1 - l2).abs() < 1e-5);
    assert_eq!(t1a, t1b);
    assert_eq!(t5a, t5b);
    std::fs::remove_dir_all(&cfg.out_dir).ok();
}

#[test]
fn training_is_deterministic_given_seed() {
    let engine = Engine::new(&artifacts()).unwrap();
    let run = |tag: &str| {
        let mut cfg = quick_cfg(2);
        cfg.name = format!("det_{tag}");
        cfg.train.max_steps = 4;
        let mut tr = Trainer::new(&engine, cfg.clone()).unwrap();
        tr.verbose = false;
        let rep = tr.fit().unwrap();
        std::fs::remove_dir_all(&cfg.out_dir).ok();
        rep.history.steps.iter().map(|s| s.loss).collect::<Vec<_>>()
    };
    assert_eq!(run("a"), run("b"));
}

#[test]
fn fp32_finetune_mapping_copies_weights_and_reinits_steps() {
    let engine = Engine::new(&artifacts()).unwrap();
    let m = engine.manifest();

    // fabricate an "fp32 checkpoint" with recognizable weights
    let fam32 = m.family("cnn_small_q32").unwrap().clone();
    let mut st32 = TrainState::fresh(m, "cnn_small_q32").unwrap();
    let widx = fam32.param_names.iter().position(|n| n == "conv2.w").unwrap();
    for v in st32.params[widx].f32s_mut().unwrap() {
        *v *= 5.0;
    }
    let dir = std::env::temp_dir().join(format!("lsq_map_{}", std::process::id()));
    let ck_path = dir.join("fp32.ckpt");
    st32.save(&fam32, &ck_path).unwrap();

    let mut cfg = quick_cfg(2);
    cfg.init_from = ck_path.to_string_lossy().to_string();
    let tr = Trainer::new(&engine, cfg).unwrap();

    let fam2 = m.family("cnn_small_q2").unwrap().clone();
    // weights copied
    let w = tr.state.param(&fam2, "conv2.w").unwrap().f32s().unwrap().to_vec();
    let src = st32.params[widx].f32s().unwrap();
    assert_eq!(w, src);
    // step size re-derived from the *scaled* weights: 2<|w|>/sqrt(Qp), Qp=1
    let expect = 2.0 * lsqnet::util::stats::mean_abs(&w) as f32;
    let sw = tr.state.param(&fam2, "conv2.sw").unwrap().item_f32().unwrap();
    assert!((sw - expect).abs() / expect < 1e-3, "sw={sw} expect={expect}");
    // activation steps positive and not the placeholder 1.0
    let sa = tr.state.param(&fam2, "conv2.sa").unwrap().item_f32().unwrap();
    assert!(sa > 0.0 && (sa - 1.0).abs() > 1e-6);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn eval_counts_are_consistent_with_logits() {
    let engine = Engine::new(&artifacts()).unwrap();
    let exe = engine.load_kind("eval", "cnn_small_q2", None, None).unwrap();
    let m = engine.manifest();
    let mut inputs = m.load_initial_params("cnn_small_q2").unwrap();
    let cfg = quick_cfg(2);
    let ds = Dataset::test(&cfg.data);
    let b = ds.batch_from_indices(&(0..64).collect::<Vec<_>>(), 64);
    let y = b.y.clone();
    inputs.push(b.x);
    inputs.push(b.y);
    let out = exe.run(&inputs).unwrap();
    let ncorrect = out[1].item_f32().unwrap() as usize;
    let recount = lsqnet::train::metrics::topk_correct(
        out[2].f32s().unwrap(),
        y.i32s().unwrap(),
        10,
        1,
        64,
    );
    assert_eq!(ncorrect, recount);
}

#[test]
fn engine_validates_inputs() {
    let engine = Engine::new(&artifacts()).unwrap();
    let exe = engine.load_kind("eval", "cnn_small_q2", None, None).unwrap();
    // wrong arity
    assert!(exe.run(&[Tensor::scalar_f32(1.0)]).is_err());
    // wrong shape in slot 0
    let m = engine.manifest();
    let mut inputs = m.load_initial_params("cnn_small_q2").unwrap();
    let cfg = quick_cfg(2);
    let ds = Dataset::test(&cfg.data);
    let b = ds.batch_from_indices(&[0], 64);
    inputs.push(b.x);
    inputs.push(b.y);
    inputs[0] = Tensor::zeros(&[1, 2, 3]);
    assert!(exe.run(&inputs).is_err());
}

#[test]
fn distill_artifact_trains() {
    let engine = Engine::new(&artifacts()).unwrap();
    if engine.manifest().artifacts.values().all(|a| a.kind != "train_kd") {
        eprintln!("skipping: no train_kd artifact in this set");
        return;
    }
    let mut cfg = quick_cfg(2);
    cfg.name = "it_kd".into();
    cfg.distill = true;
    cfg.train.max_steps = 2;
    let mut tr = Trainer::new(&engine, cfg.clone()).unwrap();
    tr.verbose = false;
    let rep = tr.fit().unwrap();
    assert_eq!(rep.history.steps.len(), 2);
    assert!(rep.history.steps[0].loss.is_finite());
    std::fs::remove_dir_all(&cfg.out_dir).ok();
}

#[test]
fn serve_round_trip_and_batching() {
    use lsqnet::serve::{Server, ServerConfig};
    let server = Server::start(ServerConfig {
        backend: lsqnet::runtime::BackendSpec::xla(&artifacts()),
        family: "cnn_small_q2".into(),
        checkpoint: String::new(),
        max_wait: std::time::Duration::from_millis(4),
        queue_depth: 128,
        replicas: 1,
        intra_threads: 0,
        fused_unpack: false,
    })
    .unwrap();
    let spec = SynthSpec::new(10, 1.2, 3);
    let mut lats = Vec::new();
    std::thread::scope(|s| {
        let hs: Vec<_> = (0..4)
            .map(|t| {
                let c = server.client().unwrap();
                let spec = &spec;
                s.spawn(move || {
                    (0..10)
                        .map(|i| c.infer(spec.generate_alloc(t * 1000 + i)).unwrap())
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        for h in hs {
            lats.extend(h.join().unwrap());
        }
    });
    let stats = server.stats();
    server.stop();
    assert_eq!(lats.len(), 40);
    assert_eq!(stats.requests, 40);
    assert!(stats.batches < 40, "batching should coalesce some requests");
    for r in &lats {
        assert_eq!(r.logits.len(), 10);
        assert!(r.total_ms > 0.0);
    }
}

#[test]
fn serve_rejects_bad_image_size() {
    use lsqnet::serve::{Server, ServerConfig};
    let server = Server::start(ServerConfig {
        backend: lsqnet::runtime::BackendSpec::xla(&artifacts()),
        family: "cnn_small_q2".into(),
        checkpoint: String::new(),
        max_wait: std::time::Duration::from_millis(1),
        queue_depth: 8,
        replicas: 1,
        intra_threads: 0,
        fused_unpack: false,
    })
    .unwrap();
    assert!(server.client().unwrap().submit(vec![0.0; 7]).is_err());
    server.stop();
}

#[test]
fn sweep_coordinator_runs_parallel_jobs() {
    let mut jobs = Vec::new();
    for (i, bits) in [2u32, 4].iter().enumerate() {
        let mut cfg = quick_cfg(*bits);
        cfg.name = format!("sweep_it_{i}");
        cfg.train.max_steps = 2;
        jobs.push(lsqnet::coordinator::Job::new(cfg).tag("bits", bits));
    }
    let out_dir = quick_cfg(2).out_dir;
    let rep = lsqnet::coordinator::run_sweep(&artifacts(), jobs, 2).unwrap();
    assert_eq!(rep.results.len(), 2);
    for r in &rep.results {
        assert!(r.error.is_none(), "{:?}", r.error);
        assert!(r.top1.is_finite());
    }
    assert!(rep.by_tags(&[("bits", "2")]).is_some());
    assert!(rep.by_tags(&[("bits", "4")]).is_some());
    std::fs::remove_dir_all(out_dir).ok();
}

#[test]
fn rratio_diag_measures_imbalance_ordering() {
    // Section 2.2 / Figure 4: R(g=1) >> R(g=1/sqrt(N*Qp)) ≈ 1.
    let engine = Engine::new(&artifacts()).unwrap();
    let mut cfg = quick_cfg(2);
    cfg.data.train_size = 256;
    let r_one = lsqnet::analyze::rratio::measure(&engine, &cfg, "one", 5).unwrap();
    let r_full = lsqnet::analyze::rratio::measure(&engine, &cfg, "full", 5).unwrap();
    let g1 = r_one.geomean_r();
    let gf = r_full.geomean_r();
    assert!(g1 > 50.0 * gf, "R(g=1)={g1:.1} should dwarf R(full)={gf:.3}");
    assert!(gf > 0.01 && gf < 100.0, "R(full)={gf} should be near 1");
}
