//! Kernel-layer tests: the determinism and workspace-reuse guarantees the
//! unified kernel layer advertises (DESIGN.md §Kernel-layer).
//!
//! * threaded `qgemm` is **bitwise identical** to single-thread at every
//!   bit width and across tile-straddling shapes;
//! * the threaded fp32 family (`sgemm`/`sgemm_nt`/`sgemm_tn`) matches
//!   single-thread bitwise (the spec floor is 1e-5; the implementation is
//!   exactly deterministic because the per-element accumulation order
//!   never depends on the split, and the test pins that);
//! * one `Workspace` pushed through back-to-back mismatched shapes gives
//!   the same results as fresh buffers per call, for raw kernels, the
//!   native inference forward, and a native train step.
//!
//! The CI gate re-runs this suite with `LSQNET_THREADS=1`, which forces
//! every kernel serial — both runs must pass unchanged.

use lsqnet::quant::lsq::qrange;
use lsqnet::quant::pack::quantize_and_pack;
use lsqnet::runtime::kernels::{qgemm, sgemm, sgemm_nt, sgemm_tn, Workspace, KC, NC};
use lsqnet::runtime::native::fixture::{write_synthetic_family, FixtureSpec};
use lsqnet::runtime::native::NativeModel;
use lsqnet::runtime::Manifest;
use lsqnet::train::native::NativeTrainModel;
use lsqnet::util::rng::Pcg32;

mod common;

const CASES: u64 = 20;

/// Run `f` over CASES seeded cases, reporting the failing seed
/// (shared mini-framework in tests/common/mod.rs).
fn forall(name: &str, f: impl FnMut(&mut Pcg32)) {
    common::forall(name, 0x6e77_0000, CASES, f);
}

/// Random GEMM shape. Half the cases are big, KC/NC-tile-straddling
/// shapes whose total work clears the kernels' per-thread spawn floor
/// (`MIN_MACS_PER_THREAD` × 2 at minimum: 16·256·64 ≈ 262k MACs), so the
/// threaded split genuinely runs; the other half are small edge shapes
/// that exercise the serial path and boundary geometry.
fn rand_shape(rng: &mut Pcg32) -> (usize, usize, usize) {
    if rng.bool(0.5) {
        (
            16 + rng.below(64) as usize,
            KC + rng.below(40) as usize,
            NC + rng.below(16) as usize,
        )
    } else {
        (
            1 + rng.below(80) as usize,
            1 + rng.below(96) as usize,
            1 + rng.below(48) as usize,
        )
    }
}

#[test]
fn prop_qgemm_threaded_bitwise_identical_to_single_thread() {
    forall("qgemm_threaded", |rng| {
        let (m, k, n) = rand_shape(rng);
        let bits = [2u32, 3, 4, 8][rng.below(4) as usize];
        let (_, qp) = qrange(bits, false);
        // ~25% zeros to exercise the zero-skip path.
        let x: Vec<i32> = (0..m * k)
            .map(|_| {
                if rng.bool(0.25) {
                    0
                } else {
                    rng.below(qp as u32 + 1) as i32
                }
            })
            .collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.4).collect();
        let packed = quantize_and_pack(&w, 0.05, bits, true).unwrap();
        let bias: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let use_bias = rng.bool(0.5);
        let b = if use_bias { Some(&bias[..]) } else { None };

        let mut ws1 = Workspace::with_threads(1);
        let mut out1 = vec![0.0f32; m * n];
        qgemm(&mut ws1, m, k, n, &x, &packed, 0.03, b, &mut out1);
        for threads in [2usize, 4, 7] {
            let mut wst = Workspace::with_threads(threads);
            let mut outt = vec![0.0f32; m * n];
            qgemm(&mut wst, m, k, n, &x, &packed, 0.03, b, &mut outt);
            for (i, (a, bb)) in out1.iter().zip(&outt).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    bb.to_bits(),
                    "qgemm t{threads} differs at {i} (m={m} k={k} n={n} bits={bits})"
                );
            }
        }
    });
}

#[test]
fn prop_sgemm_family_threaded_matches_single_thread() {
    forall("sgemm_family_threaded", |rng| {
        let (m, k, n) = rand_shape(rng);
        let x: Vec<f32> = (0..m * k)
            .map(|_| if rng.bool(0.2) { 0.0 } else { rng.normal() })
            .collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let a: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let bias: Vec<f32> = (0..n).map(|_| rng.normal()).collect();

        let mut ws1 = Workspace::with_threads(1);
        let mut s1 = vec![0.0f32; m * n];
        sgemm(&mut ws1, m, k, n, &x, &w, Some(&bias), &mut s1);
        let mut nt1 = vec![0.0f32; m * k];
        sgemm_nt(&mut ws1, m, k, n, &a, &w, &mut nt1);
        let mut tn1 = vec![0.0f32; k * n];
        sgemm_tn(&mut ws1, m, k, n, &x, &a, &mut tn1);

        for threads in [2usize, 5] {
            let mut wst = Workspace::with_threads(threads);
            let mut st = vec![0.0f32; m * n];
            sgemm(&mut wst, m, k, n, &x, &w, Some(&bias), &mut st);
            let mut ntt = vec![0.0f32; m * k];
            sgemm_nt(&mut wst, m, k, n, &a, &w, &mut ntt);
            let mut tnt = vec![0.0f32; k * n];
            sgemm_tn(&mut wst, m, k, n, &x, &a, &mut tnt);
            for (name, one, many) in
                [("sgemm", &s1, &st), ("sgemm_nt", &nt1, &ntt), ("sgemm_tn", &tn1, &tnt)]
            {
                for (i, (p, q)) in one.iter().zip(many).enumerate() {
                    // The spec floor is 1e-5, but the implementation
                    // guarantees bitwise identity (per-element order never
                    // depends on the split) — pin the stronger property so
                    // a reassociating "optimization" can't silently void
                    // the determinism story.
                    assert_eq!(
                        p.to_bits(),
                        q.to_bits(),
                        "{name} t{threads} differs at {i}: {p} vs {q} (m={m} k={k} n={n})"
                    );
                }
            }
        }
    });
}

/// The workspace-reuse satellite: run mismatched shapes back-to-back
/// through ONE workspace and check every result matches a fresh-workspace
/// run — pooled buffers must never leak state between calls.
#[test]
fn workspace_reuse_mismatched_shapes_matches_fresh_buffers() {
    let shapes = [
        (7usize, KC + 3, NC + 1),
        (1, 5, 3),
        (12, 64, 48),
        (3, 200, 9),
        (1, 1, 1),
        (8, 96, 32),
    ];
    let mut shared = Workspace::new();
    for (case, &(m, k, n)) in shapes.iter().enumerate() {
        let mut rng = Pcg32::seeded(900 + case as u64);
        let xq: Vec<i32> = (0..m * k).map(|_| rng.below(4) as i32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.4).collect();
        let packed = quantize_and_pack(&w, 0.05, 4, true).unwrap();
        let xf: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();

        let mut q_shared = vec![0.0f32; m * n];
        qgemm(&mut shared, m, k, n, &xq, &packed, 0.02, None, &mut q_shared);
        let mut s_shared = vec![0.0f32; m * n];
        sgemm(&mut shared, m, k, n, &xf, &w, None, &mut s_shared);

        let mut fresh = Workspace::new();
        let mut q_fresh = vec![0.0f32; m * n];
        qgemm(&mut fresh, m, k, n, &xq, &packed, 0.02, None, &mut q_fresh);
        let mut fresh2 = Workspace::new();
        let mut s_fresh = vec![0.0f32; m * n];
        sgemm(&mut fresh2, m, k, n, &xf, &w, None, &mut s_fresh);

        assert_eq!(q_shared, q_fresh, "qgemm case {case} (m={m} k={k} n={n})");
        assert_eq!(s_shared, s_fresh, "sgemm case {case} (m={m} k={k} n={n})");
    }
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("lsq_kern_{tag}_{}", std::process::id()))
}

/// End-to-end workspace reuse through the native inference forward:
/// repeated mixed-batch forwards through one workspace equal fresh-workspace
/// runs bitwise, on both a conv/pool arch and a residual arch.
#[test]
fn native_forward_shared_workspace_matches_fresh() {
    for (model, qbits) in [("cnn_small", 2u32), ("resnet8", 4)] {
        let dir = tmp_dir(model);
        std::fs::remove_dir_all(&dir).ok();
        let spec = FixtureSpec { image: 16, channels: 3, num_classes: 6, batch: 4, seed: 17 };
        let family = write_synthetic_family(&dir, model, qbits, spec).unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let params = manifest.load_initial_params(&family).unwrap();
        let net = NativeModel::build(&manifest, &family, &params).unwrap();

        let mut shared = Workspace::new();
        let mut rng = Pcg32::seeded(5);
        for rows in [3usize, 1, 4, 2] {
            let x: Vec<f32> = (0..rows * net.image_len()).map(|_| rng.normal()).collect();
            let y_shared = net.forward(&mut shared, &x, rows).unwrap();
            let mut fresh = Workspace::new();
            let y_fresh = net.forward(&mut fresh, &x, rows).unwrap();
            assert_eq!(y_shared, y_fresh, "{model} rows={rows}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// Same for training: two identical `loss_and_grads` calls through one
/// reused workspace must agree with a fresh-workspace call — gradients,
/// loss, logits and BN state updates alike.
#[test]
fn train_step_shared_workspace_matches_fresh() {
    let dir = tmp_dir("train");
    std::fs::remove_dir_all(&dir).ok();
    let spec = FixtureSpec { image: 8, channels: 3, num_classes: 5, batch: 2, seed: 23 };
    let family = write_synthetic_family(&dir, "cnn_small", 2, spec).unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let params = manifest.load_initial_params(&family).unwrap();
    let net = NativeTrainModel::build(&manifest, &family, "lsq", "full").unwrap();

    let rows = 2usize;
    let mut rng = Pcg32::seeded(31);
    let x: Vec<f32> = (0..rows * net.image_len()).map(|_| rng.normal()).collect();
    let y = vec![0i32, 3];

    let mut shared = Workspace::new();
    // Warm the pools with a first step, then measure the second.
    let _ = net.loss_and_grads(&mut shared, &params, &x, &y, rows).unwrap();
    let warm = net.loss_and_grads(&mut shared, &params, &x, &y, rows).unwrap();
    let mut fresh = Workspace::new();
    let cold = net.loss_and_grads(&mut fresh, &params, &x, &y, rows).unwrap();

    assert_eq!(warm.loss.to_bits(), cold.loss.to_bits(), "loss");
    assert_eq!(warm.ncorrect, cold.ncorrect);
    assert_eq!(warm.logits, cold.logits, "logits");
    assert_eq!(warm.grads.len(), cold.grads.len());
    for (i, (a, b)) in warm.grads.iter().zip(&cold.grads).enumerate() {
        assert_eq!(a.f32s().unwrap(), b.f32s().unwrap(), "grad slot {i}");
    }
    assert_eq!(warm.state_updates.len(), cold.state_updates.len());
    for ((ia, ta), (ib, tb)) in warm.state_updates.iter().zip(&cold.state_updates) {
        assert_eq!(ia, ib);
        assert_eq!(ta.f32s().unwrap(), tb.f32s().unwrap(), "state update {ia}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Threaded end-to-end: the engine forward under different intra-op caps
/// gives identical logits (the serve determinism story).
#[test]
fn native_forward_identical_across_intra_op_thread_caps() {
    let dir = tmp_dir("caps");
    std::fs::remove_dir_all(&dir).ok();
    let spec = FixtureSpec { image: 16, channels: 3, num_classes: 6, batch: 8, seed: 11 };
    let family = write_synthetic_family(&dir, "cnn_small", 2, spec).unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let params = manifest.load_initial_params(&family).unwrap();
    let net = NativeModel::build(&manifest, &family, &params).unwrap();
    let mut rng = Pcg32::seeded(2);
    let x: Vec<f32> = (0..8 * net.image_len()).map(|_| rng.normal()).collect();
    let mut ws1 = Workspace::with_threads(1);
    let base = net.forward(&mut ws1, &x, 8).unwrap();
    for threads in [2usize, 4] {
        let mut wst = Workspace::with_threads(threads);
        let got = net.forward(&mut wst, &x, 8).unwrap();
        assert_eq!(base, got, "threads={threads}");
    }
    std::fs::remove_dir_all(&dir).ok();
}
