//! Kernel-layer tests: the determinism and workspace-reuse guarantees the
//! unified kernel layer advertises (DESIGN.md §Kernel-layer,
//! §SIMD-dispatch).
//!
//! * threaded `qgemm` is **bitwise identical** to single-thread at every
//!   bit width and across tile-straddling shapes;
//! * `qgemm` is also bitwise identical across **every available SIMD
//!   level** (each rung pinned via `Workspace::force_level`), across
//!   weight storage modes (fused unpack vs bind-time panels), *and*
//!   across panel blocking geometries — including the autotuned one,
//!   which must be a pure time optimization;
//! * the threaded fp32 family (`sgemm`/`sgemm_nt`/`sgemm_tn`) matches
//!   single-thread bitwise (the spec floor is 1e-5; the implementation is
//!   exactly deterministic because the per-element accumulation order
//!   never depends on the split, and the test pins that). Across
//!   dispatch levels, `sgemm`/`sgemm_tn` stay bitwise (elementwise axpy)
//!   while `sgemm_nt` is held to 1e-5 (reassociated dot);
//! * `qgemm`'s i32 accumulation is exact at `k` just under the
//!   `check_accumulator_bound` limit (vs an i64 naive reference);
//! * one `Workspace` pushed through back-to-back mismatched shapes gives
//!   the same results as fresh buffers per call, for raw kernels, the
//!   native inference forward, and a native train step.
//!
//! The CI gate re-runs this suite with `LSQNET_THREADS=1` (forces every
//! kernel serial), with `LSQNET_FORCE_SCALAR=1` (pins the portable SIMD
//! path), with `LSQNET_SIMD=<level>` for every level `lsqnet simd-levels`
//! reports (the forced-level matrix), and with `LSQNET_FMA=1` (the fp32
//! FMA tier as the default) — all runs must pass unchanged, so CI on any
//! host exercises every rung of the dispatch ladder it can execute.

use lsqnet::quant::lsq::qrange;
use lsqnet::quant::pack::quantize_and_pack;
use lsqnet::runtime::kernels::{
    qgemm, qgemm_panel, sgemm, sgemm_nt, sgemm_tn, FpMode, PanelGeom, PanelizedWeights, SimdLevel,
    Workspace, KC, NC,
};
use lsqnet::runtime::native::fixture::{write_synthetic_family, FixtureSpec};
use lsqnet::runtime::native::{NativeModel, UnpackMode};
use lsqnet::runtime::Manifest;
use lsqnet::train::native::NativeTrainModel;
use lsqnet::util::rng::Pcg32;

mod common;

const CASES: u64 = 20;

/// Run `f` over CASES seeded cases, reporting the failing seed
/// (shared mini-framework in tests/common/mod.rs).
fn forall(name: &str, f: impl FnMut(&mut Pcg32)) {
    common::forall(name, 0x6e77_0000, CASES, f);
}

/// Random GEMM shape. Half the cases are big, KC/NC-tile-straddling
/// shapes whose total work clears the kernels' per-thread spawn floor
/// (`MIN_MACS_PER_THREAD` × 2 at minimum: 16·256·64 ≈ 262k MACs), so the
/// threaded split genuinely runs; the other half are small edge shapes
/// that exercise the serial path and boundary geometry.
fn rand_shape(rng: &mut Pcg32) -> (usize, usize, usize) {
    if rng.bool(0.5) {
        (
            16 + rng.below(64) as usize,
            KC + rng.below(40) as usize,
            NC + rng.below(16) as usize,
        )
    } else {
        (
            1 + rng.below(80) as usize,
            1 + rng.below(96) as usize,
            1 + rng.below(48) as usize,
        )
    }
}

#[test]
fn prop_qgemm_threaded_bitwise_identical_to_single_thread() {
    forall("qgemm_threaded", |rng| {
        let (m, k, n) = rand_shape(rng);
        let bits = [2u32, 3, 4, 8][rng.below(4) as usize];
        let (_, qp) = qrange(bits, false);
        // ~25% zeros to exercise the zero-skip path.
        let x: Vec<i32> = (0..m * k)
            .map(|_| {
                if rng.bool(0.25) {
                    0
                } else {
                    rng.below(qp as u32 + 1) as i32
                }
            })
            .collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.4).collect();
        let packed = quantize_and_pack(&w, 0.05, bits, true).unwrap();
        let bias: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
        let use_bias = rng.bool(0.5);
        let b = if use_bias { Some(&bias[..]) } else { None };

        let mut ws1 = Workspace::with_threads(1);
        let mut out1 = vec![0.0f32; m * n];
        qgemm(&mut ws1, m, k, n, &x, &packed, 0.03, b, &mut out1);
        for threads in [2usize, 4, 7] {
            let mut wst = Workspace::with_threads(threads);
            let mut outt = vec![0.0f32; m * n];
            qgemm(&mut wst, m, k, n, &x, &packed, 0.03, b, &mut outt);
            for (i, (a, bb)) in out1.iter().zip(&outt).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    bb.to_bits(),
                    "qgemm t{threads} differs at {i} (m={m} k={k} n={n} bits={bits})"
                );
            }
        }
    });
}

#[test]
fn prop_sgemm_family_threaded_matches_single_thread() {
    forall("sgemm_family_threaded", |rng| {
        let (m, k, n) = rand_shape(rng);
        let x: Vec<f32> = (0..m * k)
            .map(|_| if rng.bool(0.2) { 0.0 } else { rng.normal() })
            .collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let a: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
        let bias: Vec<f32> = (0..n).map(|_| rng.normal()).collect();

        let mut ws1 = Workspace::with_threads(1);
        let mut s1 = vec![0.0f32; m * n];
        sgemm(&mut ws1, m, k, n, &x, &w, Some(&bias), &mut s1);
        let mut nt1 = vec![0.0f32; m * k];
        sgemm_nt(&mut ws1, m, k, n, &a, &w, &mut nt1);
        let mut tn1 = vec![0.0f32; k * n];
        sgemm_tn(&mut ws1, m, k, n, &x, &a, &mut tn1);

        for threads in [2usize, 5] {
            let mut wst = Workspace::with_threads(threads);
            let mut st = vec![0.0f32; m * n];
            sgemm(&mut wst, m, k, n, &x, &w, Some(&bias), &mut st);
            let mut ntt = vec![0.0f32; m * k];
            sgemm_nt(&mut wst, m, k, n, &a, &w, &mut ntt);
            let mut tnt = vec![0.0f32; k * n];
            sgemm_tn(&mut wst, m, k, n, &x, &a, &mut tnt);
            for (name, one, many) in
                [("sgemm", &s1, &st), ("sgemm_nt", &nt1, &ntt), ("sgemm_tn", &tn1, &tnt)]
            {
                for (i, (p, q)) in one.iter().zip(many).enumerate() {
                    // The spec floor is 1e-5, but the implementation
                    // guarantees bitwise identity (per-element order never
                    // depends on the split) — pin the stronger property so
                    // a reassociating "optimization" can't silently void
                    // the determinism story.
                    assert_eq!(
                        p.to_bits(),
                        q.to_bits(),
                        "{name} t{threads} differs at {i}: {p} vs {q} (m={m} k={k} n={n})"
                    );
                }
            }
        }
    });
}

/// SIMD-ladder × storage × geometry parity: **every** dispatch level the
/// host can run (pinned via `Workspace::force_level` — the in-process
/// analog of `LSQNET_SIMD`), both weight storage modes (fused unpack and
/// bind-time panels), several panel blocking geometries (the legacy
/// default, a deeper-k rival, the 16-wide VNNI shape, and — when the
/// activation grid fits i8 — the `ki=4` interleave), and the threaded
/// split must all agree **bitwise** with the forced-scalar reference at
/// every bit width. i32 accumulation is exact, so neither the lane
/// order, the panel layout, nor the blocking may change a single bit —
/// this is the invariant the bind-time autotuner's safety rests on.
#[test]
fn prop_qgemm_dispatch_and_panel_bitwise_parity() {
    let levels = SimdLevel::available_levels();
    forall("qgemm_dispatch_panel", |rng| {
        let (m, k, n) = rand_shape(rng);
        let bits = [2u32, 3, 4, 8][rng.below(4) as usize];
        let (_, qp) = qrange(bits, false);
        let x: Vec<i32> = (0..m * k)
            .map(|_| {
                if rng.bool(0.25) {
                    0
                } else {
                    rng.below(qp as u32 + 1) as i32
                }
            })
            .collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.4).collect();
        let packed = quantize_and_pack(&w, 0.05, bits, true).unwrap();
        let mut geoms = vec![
            PanelGeom::DEFAULT,
            PanelGeom { kc: 128, nc: 128, nr: 8, ki: 2 },
            PanelGeom { kc: 256, nc: 64, nr: 16, ki: 2 },
        ];
        if qp <= 127 {
            // ki=4 panels require i8-range activations; levels without a
            // quad microkernel decode them on the geometry-generic
            // scalar path, which must still agree bitwise.
            geoms.push(PanelGeom { kc: 256, nc: 64, nr: 8, ki: 4 });
        }
        let panels: Vec<PanelizedWeights> = geoms
            .iter()
            .map(|&g| PanelizedWeights::build_with_geom(&packed, k, n, g))
            .collect();

        let mut scalar_ws = Workspace::with_threads(1);
        scalar_ws.force_scalar();
        let mut base = vec![0.0f32; m * n];
        qgemm(&mut scalar_ws, m, k, n, &x, &packed, 0.03, None, &mut base);

        for &level in &levels {
            for threads in [1usize, 3] {
                let mut ws = Workspace::with_threads(threads);
                assert!(ws.force_level(level), "{} reported available", level.name());
                let mut fused = vec![0.0f32; m * n];
                qgemm(&mut ws, m, k, n, &x, &packed, 0.03, None, &mut fused);
                for (i, (want, f)) in base.iter().zip(&fused).enumerate() {
                    assert_eq!(
                        want.to_bits(),
                        f.to_bits(),
                        "fused {} t{threads} differs at {i} \
                         (m={m} k={k} n={n} bits={bits})",
                        level.name()
                    );
                }
                for pw in &panels {
                    let g = pw.geom();
                    let mut paneled = vec![0.0f32; m * n];
                    qgemm_panel(&mut ws, m, k, n, &x, pw, 0.03, None, &mut paneled);
                    for (i, (want, p)) in base.iter().zip(&paneled).enumerate() {
                        assert_eq!(
                            want.to_bits(),
                            p.to_bits(),
                            "panel {} t{threads} kc{}/nc{}/nr{}/ki{} differs at {i} \
                             (m={m} k={k} n={n} bits={bits})",
                            level.name(),
                            g.kc,
                            g.nc,
                            g.nr,
                            g.ki
                        );
                    }
                }
            }
        }
    });
}

/// fp32 family across the dispatch: `sgemm`/`sgemm_tn` use an elementwise
/// axpy inner loop (one mul + one add per element at every level) and
/// must stay bitwise; `sgemm_nt`'s dot reduction reassociates in SIMD
/// lanes and is held to the layer's 1e-5 relative tolerance.
#[test]
fn prop_sgemm_family_simd_vs_scalar_dispatch() {
    forall("sgemm_family_dispatch", |rng| {
        let (m, k, n) = rand_shape(rng);
        let x: Vec<f32> = (0..m * k)
            .map(|_| if rng.bool(0.2) { 0.0 } else { rng.normal() })
            .collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let a: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();

        let mut sc = Workspace::with_threads(1);
        sc.force_scalar();
        let mut s_sc = vec![0.0f32; m * n];
        sgemm(&mut sc, m, k, n, &x, &w, None, &mut s_sc);
        let mut nt_sc = vec![0.0f32; m * k];
        sgemm_nt(&mut sc, m, k, n, &a, &w, &mut nt_sc);
        let mut tn_sc = vec![0.0f32; k * n];
        sgemm_tn(&mut sc, m, k, n, &x, &a, &mut tn_sc);

        let mut ws = Workspace::with_threads(1);
        let mut s = vec![0.0f32; m * n];
        sgemm(&mut ws, m, k, n, &x, &w, None, &mut s);
        let mut nt = vec![0.0f32; m * k];
        sgemm_nt(&mut ws, m, k, n, &a, &w, &mut nt);
        let mut tn = vec![0.0f32; k * n];
        sgemm_tn(&mut ws, m, k, n, &x, &a, &mut tn);

        for (i, (p, q)) in s_sc.iter().zip(&s).enumerate() {
            assert_eq!(
                p.to_bits(),
                q.to_bits(),
                "sgemm dispatch differs at {i} (m={m} k={k} n={n})"
            );
        }
        for (i, (p, q)) in tn_sc.iter().zip(&tn).enumerate() {
            assert_eq!(
                p.to_bits(),
                q.to_bits(),
                "sgemm_tn dispatch differs at {i} (m={m} k={k} n={n})"
            );
        }
        for (i, (p, q)) in nt_sc.iter().zip(&nt).enumerate() {
            assert!(
                (p - q).abs() <= 1e-5 * p.abs().max(1.0),
                "sgemm_nt dispatch at {i}: {p} vs {q} (m={m} k={k} n={n})"
            );
        }
    });
}

/// The fp32 FMA tier ([`FpMode::Fma`], `LSQNET_FMA=1`): fused mul-adds
/// round once instead of twice, so FMA results are held to the layer's
/// 1e-5 tolerance against the pinned-reassociation reference — and
/// *within* the tier the ladder must still agree: `sgemm`/`sgemm_tn`
/// (elementwise axpy, one fused rounding per element at every level) stay
/// bitwise across levels, `sgemm_nt`'s reassociated dot holds 1e-5.
/// Skipped on hosts without FMA units (`set_fp_mode` rejects the mode).
#[test]
fn prop_sgemm_fma_tier_matches_pinned_and_holds_cross_level_parity() {
    let mut probe = Workspace::with_threads(1);
    probe.set_fp_mode(FpMode::Fma);
    if probe.fp_mode() != FpMode::Fma {
        eprintln!("skipping FMA tier test: host has no FMA units");
        return;
    }
    let levels = SimdLevel::available_levels();
    forall("sgemm_fma_tier", |rng| {
        let (m, k, n) = rand_shape(rng);
        let x: Vec<f32> = (0..m * k)
            .map(|_| if rng.bool(0.2) { 0.0 } else { rng.normal() })
            .collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let a: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();

        // Pinned scalar reference (the test-oracle contraction mode).
        let mut pin = Workspace::with_threads(1);
        pin.force_scalar();
        pin.set_fp_mode(FpMode::Pinned);
        let mut s_pin = vec![0.0f32; m * n];
        sgemm(&mut pin, m, k, n, &x, &w, None, &mut s_pin);

        // Scalar FMA reference (f32::mul_add — the same correctly-rounded
        // fused operation the vector units perform).
        let mut fsc = Workspace::with_threads(1);
        fsc.force_scalar();
        fsc.set_fp_mode(FpMode::Fma);
        let mut s_fsc = vec![0.0f32; m * n];
        sgemm(&mut fsc, m, k, n, &x, &w, None, &mut s_fsc);
        let mut nt_fsc = vec![0.0f32; m * k];
        sgemm_nt(&mut fsc, m, k, n, &a, &w, &mut nt_fsc);
        let mut tn_fsc = vec![0.0f32; k * n];
        sgemm_tn(&mut fsc, m, k, n, &x, &a, &mut tn_fsc);

        for (i, (p, q)) in s_pin.iter().zip(&s_fsc).enumerate() {
            assert!(
                (p - q).abs() <= 1e-5 * p.abs().max(1.0),
                "sgemm fma-vs-pinned at {i}: {p} vs {q} (m={m} k={k} n={n})"
            );
        }

        for &level in &levels {
            let mut ws = Workspace::with_threads(1);
            assert!(ws.force_level(level));
            ws.set_fp_mode(FpMode::Fma);
            let mut s = vec![0.0f32; m * n];
            sgemm(&mut ws, m, k, n, &x, &w, None, &mut s);
            let mut nt = vec![0.0f32; m * k];
            sgemm_nt(&mut ws, m, k, n, &a, &w, &mut nt);
            let mut tn = vec![0.0f32; k * n];
            sgemm_tn(&mut ws, m, k, n, &x, &a, &mut tn);
            for (i, (p, q)) in s_fsc.iter().zip(&s).enumerate() {
                assert_eq!(
                    p.to_bits(),
                    q.to_bits(),
                    "sgemm fma {} differs at {i} (m={m} k={k} n={n})",
                    level.name()
                );
            }
            for (i, (p, q)) in tn_fsc.iter().zip(&tn).enumerate() {
                assert_eq!(
                    p.to_bits(),
                    q.to_bits(),
                    "sgemm_tn fma {} differs at {i} (m={m} k={k} n={n})",
                    level.name()
                );
            }
            for (i, (p, q)) in nt_fsc.iter().zip(&nt).enumerate() {
                assert!(
                    (p - q).abs() <= 1e-5 * p.abs().max(1.0),
                    "sgemm_nt fma {} at {i}: {p} vs {q} (m={m} k={k} n={n})",
                    level.name()
                );
            }
        }
    });
}

/// The bind-time autotuner end to end: panels built through
/// `build_for_acts` (whatever geometry the timer picked) produce logits
/// bitwise identical to default-geometry panels, and a second bind of
/// the same model hits the process-wide cache instead of re-timing.
#[test]
fn autotuned_panels_match_default_bitwise_and_cache_reuses_across_binds() {
    use lsqnet::runtime::kernels::tune;
    // Kernel-level: tuned-vs-default geometry on one shape, bitwise.
    let mut rng = Pcg32::seeded(77);
    let (m, k, n, bits) = (9usize, 130usize, 70usize, 4u32);
    let (_, qp) = qrange(bits, false);
    let x: Vec<i32> = (0..m * k).map(|_| rng.below(qp as u32 + 1) as i32).collect();
    let w: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.4).collect();
    let packed = quantize_and_pack(&w, 0.05, bits, true).unwrap();
    let dflt = PanelizedWeights::build(&packed, k, n);
    let tuned = PanelizedWeights::build_for_acts(&packed, k, n, qp);
    assert!(tuned.geom().valid());
    let mut ws = Workspace::new();
    let mut out_d = vec![0.0f32; m * n];
    qgemm_panel(&mut ws, m, k, n, &x, &dflt, 0.03, None, &mut out_d);
    let mut out_t = vec![0.0f32; m * n];
    qgemm_panel(&mut ws, m, k, n, &x, &tuned, 0.03, None, &mut out_t);
    for (i, (d, t)) in out_d.iter().zip(&out_t).enumerate() {
        assert_eq!(
            d.to_bits(),
            t.to_bits(),
            "tuned geometry changed qgemm output at {i} (geom {:?})",
            tuned.geom()
        );
    }

    // Model-level: a panelized bind tunes through the same cache; a
    // second bind of the same family adds no new entries and produces
    // bitwise-identical logits.
    let dir = tmp_dir("tunecache");
    std::fs::remove_dir_all(&dir).ok();
    let spec = FixtureSpec { image: 16, channels: 3, num_classes: 6, batch: 4, seed: 59 };
    let family = write_synthetic_family(&dir, "cnn_small", 3, spec).unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let params = manifest.load_initial_params(&family).unwrap();
    let net1 = NativeModel::build_with_mode(&manifest, &family, &params, UnpackMode::Panelized)
        .unwrap();
    let len_after_first = tune::cache_len();
    let net2 = NativeModel::build_with_mode(&manifest, &family, &params, UnpackMode::Panelized)
        .unwrap();
    assert_eq!(
        tune::cache_len(),
        len_after_first,
        "re-binding the same model must reuse the tuning cache"
    );
    let mut rng = Pcg32::seeded(60);
    let x: Vec<f32> = (0..2 * net1.image_len()).map(|_| rng.normal()).collect();
    let mut ws1 = Workspace::new();
    let mut ws2 = Workspace::new();
    let y1 = net1.forward(&mut ws1, &x, 2).unwrap();
    let y2 = net2.forward(&mut ws2, &x, 2).unwrap();
    assert_eq!(y1, y2, "re-bound model logits must match bitwise");
    std::fs::remove_dir_all(&dir).ok();
}

/// i32 exactness at the accumulator edge: `k` just under the
/// `check_accumulator_bound` limit for 8-bit (the worst case — unsigned
/// activations at Qp=255 against signed weights at ±128), adversarial
/// same-sign values, checked against an i64 naive reference. The small-k
/// unit test in `gemm.rs` covers correctness; this pins the bound.
#[test]
fn qgemm_exact_at_k_near_accumulator_bound() {
    let (m, k, n) = (2usize, 65_000usize, 3usize);
    assert!(lsqnet::runtime::kernels::check_accumulator_bound(k, 255, 0, 128, 127));
    let mut rng = Pcg32::seeded(65);
    // Mostly extreme magnitudes, aligned in sign so partial sums push
    // toward the i32 edge instead of cancelling.
    let x: Vec<i32> = (0..m * k).map(|_| if rng.bool(0.9) { 255 } else { 1 }).collect();
    let wv: Vec<i32> = (0..k * n).map(|_| if rng.bool(0.9) { -128 } else { 127 }).collect();
    let packed = quantize_and_pack(
        &wv.iter().map(|&v| v as f32).collect::<Vec<f32>>(),
        1.0,
        8,
        true,
    )
    .unwrap();
    let panels = PanelizedWeights::build(&packed, k, n);
    let mut ws = Workspace::new();
    let mut fused = vec![0.0f32; m * n];
    qgemm(&mut ws, m, k, n, &x, &packed, 1.0, None, &mut fused);
    let mut paneled = vec![0.0f32; m * n];
    qgemm_panel(&mut ws, m, k, n, &x, &panels, 1.0, None, &mut paneled);
    for i in 0..m {
        for j in 0..n {
            let want: i64 = (0..k).map(|kk| x[i * k + kk] as i64 * wv[kk * n + j] as i64).sum();
            assert!(i32::try_from(want).is_ok(), "test shape must stay in i32");
            assert_eq!(fused[i * n + j], want as f32, "fused ({i},{j})");
            assert_eq!(paneled[i * n + j], want as f32, "panel ({i},{j})");
        }
    }
}

/// End-to-end storage-mode parity: a model bound with bind-time panels
/// and one bound fused must produce bitwise-identical logits.
#[test]
fn native_forward_panelized_matches_fused_mode() {
    let dir = tmp_dir("modes");
    std::fs::remove_dir_all(&dir).ok();
    let spec = FixtureSpec { image: 16, channels: 3, num_classes: 6, batch: 4, seed: 41 };
    let family = write_synthetic_family(&dir, "cnn_small", 3, spec).unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let params = manifest.load_initial_params(&family).unwrap();
    let paneled = NativeModel::build_with_mode(&manifest, &family, &params, UnpackMode::Panelized)
        .unwrap();
    let fused =
        NativeModel::build_with_mode(&manifest, &family, &params, UnpackMode::Fused).unwrap();
    assert!(paneled.panel_bytes > 0, "panelized bind must report panel bytes");
    assert_eq!(fused.panel_bytes, 0, "fused bind holds no panels");
    assert_eq!(paneled.packed_bytes, fused.packed_bytes, "Figure-3 storage is mode-independent");
    let mut rng = Pcg32::seeded(8);
    let mut ws_p = Workspace::new();
    let mut ws_f = Workspace::new();
    for rows in [1usize, 3, 4] {
        let x: Vec<f32> = (0..rows * paneled.image_len()).map(|_| rng.normal()).collect();
        let yp = paneled.forward(&mut ws_p, &x, rows).unwrap();
        let yf = fused.forward(&mut ws_f, &x, rows).unwrap();
        assert_eq!(yp, yf, "rows={rows}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The workspace-reuse satellite: run mismatched shapes back-to-back
/// through ONE workspace and check every result matches a fresh-workspace
/// run — pooled buffers must never leak state between calls.
#[test]
fn workspace_reuse_mismatched_shapes_matches_fresh_buffers() {
    let shapes = [
        (7usize, KC + 3, NC + 1),
        (1, 5, 3),
        (12, 64, 48),
        (3, 200, 9),
        (1, 1, 1),
        (8, 96, 32),
    ];
    let mut shared = Workspace::new();
    for (case, &(m, k, n)) in shapes.iter().enumerate() {
        let mut rng = Pcg32::seeded(900 + case as u64);
        let xq: Vec<i32> = (0..m * k).map(|_| rng.below(4) as i32).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.4).collect();
        let packed = quantize_and_pack(&w, 0.05, 4, true).unwrap();
        let xf: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();

        let mut q_shared = vec![0.0f32; m * n];
        qgemm(&mut shared, m, k, n, &xq, &packed, 0.02, None, &mut q_shared);
        let mut s_shared = vec![0.0f32; m * n];
        sgemm(&mut shared, m, k, n, &xf, &w, None, &mut s_shared);

        let mut fresh = Workspace::new();
        let mut q_fresh = vec![0.0f32; m * n];
        qgemm(&mut fresh, m, k, n, &xq, &packed, 0.02, None, &mut q_fresh);
        let mut fresh2 = Workspace::new();
        let mut s_fresh = vec![0.0f32; m * n];
        sgemm(&mut fresh2, m, k, n, &xf, &w, None, &mut s_fresh);

        assert_eq!(q_shared, q_fresh, "qgemm case {case} (m={m} k={k} n={n})");
        assert_eq!(s_shared, s_fresh, "sgemm case {case} (m={m} k={k} n={n})");
    }
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("lsq_kern_{tag}_{}", std::process::id()))
}

/// End-to-end workspace reuse through the native inference forward:
/// repeated mixed-batch forwards through one workspace equal fresh-workspace
/// runs bitwise, on both a conv/pool arch and a residual arch.
#[test]
fn native_forward_shared_workspace_matches_fresh() {
    for (model, qbits) in [("cnn_small", 2u32), ("resnet8", 4)] {
        let dir = tmp_dir(model);
        std::fs::remove_dir_all(&dir).ok();
        let spec = FixtureSpec { image: 16, channels: 3, num_classes: 6, batch: 4, seed: 17 };
        let family = write_synthetic_family(&dir, model, qbits, spec).unwrap();
        let manifest = Manifest::load(&dir).unwrap();
        let params = manifest.load_initial_params(&family).unwrap();
        let net = NativeModel::build(&manifest, &family, &params).unwrap();

        let mut shared = Workspace::new();
        let mut rng = Pcg32::seeded(5);
        for rows in [3usize, 1, 4, 2] {
            let x: Vec<f32> = (0..rows * net.image_len()).map(|_| rng.normal()).collect();
            let y_shared = net.forward(&mut shared, &x, rows).unwrap();
            let mut fresh = Workspace::new();
            let y_fresh = net.forward(&mut fresh, &x, rows).unwrap();
            assert_eq!(y_shared, y_fresh, "{model} rows={rows}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// Same for training: two identical `loss_and_grads` calls through one
/// reused workspace must agree with a fresh-workspace call — gradients,
/// loss, logits and BN state updates alike.
#[test]
fn train_step_shared_workspace_matches_fresh() {
    let dir = tmp_dir("train");
    std::fs::remove_dir_all(&dir).ok();
    let spec = FixtureSpec { image: 8, channels: 3, num_classes: 5, batch: 2, seed: 23 };
    let family = write_synthetic_family(&dir, "cnn_small", 2, spec).unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let params = manifest.load_initial_params(&family).unwrap();
    let net = NativeTrainModel::build(&manifest, &family, "lsq", "full").unwrap();

    let rows = 2usize;
    let mut rng = Pcg32::seeded(31);
    let x: Vec<f32> = (0..rows * net.image_len()).map(|_| rng.normal()).collect();
    let y = vec![0i32, 3];

    let mut shared = Workspace::new();
    // Warm the pools with a first step, then measure the second.
    let _ = net.loss_and_grads(&mut shared, &params, &x, &y, rows).unwrap();
    let warm = net.loss_and_grads(&mut shared, &params, &x, &y, rows).unwrap();
    let mut fresh = Workspace::new();
    let cold = net.loss_and_grads(&mut fresh, &params, &x, &y, rows).unwrap();

    assert_eq!(warm.loss.to_bits(), cold.loss.to_bits(), "loss");
    assert_eq!(warm.ncorrect, cold.ncorrect);
    assert_eq!(warm.logits, cold.logits, "logits");
    assert_eq!(warm.grads.len(), cold.grads.len());
    for (i, (a, b)) in warm.grads.iter().zip(&cold.grads).enumerate() {
        assert_eq!(a.f32s().unwrap(), b.f32s().unwrap(), "grad slot {i}");
    }
    assert_eq!(warm.state_updates.len(), cold.state_updates.len());
    for ((ia, ta), (ib, tb)) in warm.state_updates.iter().zip(&cold.state_updates) {
        assert_eq!(ia, ib);
        assert_eq!(ta.f32s().unwrap(), tb.f32s().unwrap(), "state update {ia}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Threaded end-to-end: the engine forward under different intra-op caps
/// gives identical logits (the serve determinism story).
#[test]
fn native_forward_identical_across_intra_op_thread_caps() {
    let dir = tmp_dir("caps");
    std::fs::remove_dir_all(&dir).ok();
    let spec = FixtureSpec { image: 16, channels: 3, num_classes: 6, batch: 8, seed: 11 };
    let family = write_synthetic_family(&dir, "cnn_small", 2, spec).unwrap();
    let manifest = Manifest::load(&dir).unwrap();
    let params = manifest.load_initial_params(&family).unwrap();
    let net = NativeModel::build(&manifest, &family, &params).unwrap();
    let mut rng = Pcg32::seeded(2);
    let x: Vec<f32> = (0..8 * net.image_len()).map(|_| rng.normal()).collect();
    let mut ws1 = Workspace::with_threads(1);
    let base = net.forward(&mut ws1, &x, 8).unwrap();
    for threads in [2usize, 4] {
        let mut wst = Workspace::with_threads(threads);
        let got = net.forward(&mut wst, &x, 8).unwrap();
        assert_eq!(base, got, "threads={threads}");
    }
    std::fs::remove_dir_all(&dir).ok();
}
