//! Native-backend tests: pack → fused-GEMM → unpack parity against the
//! scalar reference quantizer in `quant::lsq` for every bit width, plus
//! end-to-end checks of the interpreted forward pass and the multi-replica
//! serve path. None of this needs Python, XLA or PJRT — the synthetic
//! fixture writes a real manifest + params bin.

use std::path::PathBuf;

use lsqnet::quant::lsq::{qrange, quantize, quantize_vbar};
use lsqnet::quant::pack::{quantize_and_pack, unpack};
use lsqnet::runtime::kernels::{qgemm, Workspace};
use lsqnet::runtime::native::fixture::{write_synthetic_family, FixtureSpec};
use lsqnet::runtime::native::NativeModel;
use lsqnet::runtime::{Backend, BackendSpec, Manifest, NativeEngine};
use lsqnet::util::rng::Pcg32;

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("lsq_native_{tag}_{}", std::process::id()))
}

/// The satellite parity test: quantize-and-pack a weight matrix at every
/// width (signed and unsigned activations), run the fused unpack-and-dot
/// GEMM, and compare each output against the scalar reference computed
/// with `quant::lsq` Eq. 1/2 math in f64.
#[test]
fn qgemm_matches_scalar_reference_for_all_widths() {
    let (m, k, n) = (4usize, 33usize, 11usize);
    for bits in 1..=8u32 {
        for act_signed in [true, false] {
            let mut rng = Pcg32::seeded(100 + bits as u64 * 2 + act_signed as u64);
            // fp32 weights + a realistic step size
            let w: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.5).collect();
            let (wqn, wqp) = qrange(bits, true);
            let sw = lsqnet::quant::lsq::step_init(&w, wqp).max(1e-3);
            let packed = quantize_and_pack(&w, sw, bits, true).unwrap();

            // fp32 activations quantized per Eq. 1 with the layer's sa
            let (aqn, aqp) = qrange(bits, act_signed);
            let sa = 0.21f32;
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let abar: Vec<i32> =
                a.iter().map(|&v| quantize_vbar(v, sa, aqn, aqp) as i32).collect();

            let mut ws = Workspace::new();
            let mut out = vec![0.0f32; m * n];
            qgemm(&mut ws, m, k, n, &abar, &packed, sa * sw, None, &mut out);

            // scalar reference: dot of Eq. 2 dequantized values, in f64
            let wbar = unpack(&packed);
            for i in 0..m {
                for j in 0..n {
                    let mut want = 0.0f64;
                    for kk in 0..k {
                        let ah = abar[i * k + kk] as f64 * sa as f64;
                        let wh = wbar[kk * n + j] as f64 * sw as f64;
                        want += ah * wh;
                    }
                    let got = out[i * n + j] as f64;
                    assert!(
                        (got - want).abs() < 1e-3 * want.abs().max(1.0),
                        "bits={bits} signed_act={act_signed} ({i},{j}): {got} vs {want}"
                    );
                }
            }

            // and the packed weights themselves dequantize to Eq. 2 exactly
            for (orig, &vb) in w.iter().zip(&wbar) {
                let eq2 = quantize(*orig, sw, wqn, wqp);
                assert_eq!(eq2, vb as f32 * sw, "bits={bits}");
            }
        }
    }
}

/// The native forward pass of an fp32 (q32) family must equal plain fp32
/// math; spot-check against a quantized build of the same weights — the
/// two differ, but only within the quantization error budget.
#[test]
fn native_forward_q32_vs_q8_are_close() {
    let spec = FixtureSpec { image: 16, channels: 3, num_classes: 10, batch: 4, seed: 5 };
    let dir32 = tmp_dir("fw32");
    let dir8 = tmp_dir("fw8");
    // Same seed => identical weights; only the quantizers differ.
    let fam32 = write_synthetic_family(&dir32, "cnn_small", 32, spec).unwrap();
    let fam8 = write_synthetic_family(&dir8, "cnn_small", 8, spec).unwrap();

    let m32 = Manifest::load(&dir32).unwrap();
    let m8 = Manifest::load(&dir8).unwrap();
    let model32 =
        NativeModel::build(&m32, &fam32, &m32.load_initial_params(&fam32).unwrap()).unwrap();
    let model8 =
        NativeModel::build(&m8, &fam8, &m8.load_initial_params(&fam8).unwrap()).unwrap();

    let mut rng = Pcg32::seeded(9);
    let x: Vec<f32> = (0..2 * 16 * 16 * 3).map(|_| rng.normal()).collect();
    let mut ws = Workspace::new();
    let y32 = model32.forward(&mut ws, &x, 2).unwrap();
    let y8 = model8.forward(&mut ws, &x, 2).unwrap();
    assert_eq!(y32.len(), 20);
    assert_eq!(y8.len(), 20);
    assert!(y32.iter().all(|v| v.is_finite()));
    // 8-bit quantization tracks fp32 closely at this depth
    let max_abs = y32.iter().fold(0.0f32, |a, &v| a.max(v.abs())).max(1e-3);
    for (a, b) in y32.iter().zip(&y8) {
        assert!(
            (a - b).abs() < 0.35 * max_abs,
            "q32 {a} vs q8 {b} (scale {max_abs})"
        );
    }
    // the q8 model actually stores packed weights
    assert!(model8.packed_bytes < model32.packed_bytes);
    std::fs::remove_dir_all(&dir32).ok();
    std::fs::remove_dir_all(&dir8).ok();
}

/// The residual (resnet) and pooling (vgg) paths build and run.
#[test]
fn native_forward_covers_resnet_and_vgg() {
    for (model, qbits) in [("resnet8", 2u32), ("vgg_small", 4), ("mlp", 2)] {
        let spec = FixtureSpec { image: 16, channels: 3, num_classes: 7, batch: 2, seed: 3 };
        let dir = tmp_dir(model);
        let family = write_synthetic_family(&dir, model, qbits, spec).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let model_rt =
            NativeModel::build(&m, &family, &m.load_initial_params(&family).unwrap()).unwrap();
        let mut rng = Pcg32::seeded(4);
        let x: Vec<f32> = (0..3 * 16 * 16 * 3).map(|_| rng.normal()).collect();
        let mut ws = Workspace::new();
        let y = model_rt.forward(&mut ws, &x, 3).unwrap();
        assert_eq!(y.len(), 3 * 7, "{model}");
        assert!(y.iter().all(|v| v.is_finite()), "{model}");
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// Backend trait plumbing: open via spec, prepare, infer a padded batch.
#[test]
fn backend_spec_opens_native_engine() {
    let dir = tmp_dir("spec");
    let spec = FixtureSpec { image: 8, channels: 3, num_classes: 5, batch: 4, seed: 21 };
    let family = write_synthetic_family(&dir, "mlp", 4, spec).unwrap();
    let mut backend = BackendSpec::native(&dir).open().unwrap();
    assert_eq!(backend.name(), "native");
    let params = backend.manifest().load_initial_params(&family).unwrap();
    backend.prepare_infer(&family, &params, &lsqnet::runtime::PrepareOptions::new()).unwrap();
    assert_eq!(backend.batch(), 4);
    let x = vec![0.5f32; 4 * 8 * 8 * 3];
    let logits = backend.infer(&x).unwrap();
    assert_eq!(logits.len(), 4 * 5);
    // all four rows identical input => identical logits
    for r in 1..4 {
        assert_eq!(&logits[r * 5..r * 5 + 5], &logits[..5]);
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// NativeEngine::infer without prepare_infer is a clean error, not a panic.
#[test]
fn infer_before_prepare_errors() {
    let dir = tmp_dir("noprep");
    let spec = FixtureSpec { image: 8, channels: 3, num_classes: 5, batch: 2, seed: 2 };
    write_synthetic_family(&dir, "mlp", 4, spec).unwrap();
    let mut engine = NativeEngine::new(&dir).unwrap();
    assert!(engine.infer(&[0.0; 8 * 8 * 3]).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

/// The multi-replica smoke test: N clients hammer a server with 3 native
/// replicas; every request gets exactly one reply and the stats add up.
#[test]
fn multi_replica_serve_answers_every_request_once() {
    use lsqnet::serve::{Server, ServerConfig};
    let dir = tmp_dir("serve");
    let spec = FixtureSpec { image: 8, channels: 3, num_classes: 6, batch: 4, seed: 33 };
    let family = write_synthetic_family(&dir, "cnn_small", 2, spec).unwrap();

    let server = Server::start(ServerConfig {
        backend: BackendSpec::native(&dir),
        family,
        checkpoint: String::new(),
        max_wait: std::time::Duration::from_millis(2),
        queue_depth: 64,
        replicas: 3,
        intra_threads: 0,
        fused_unpack: false,
    })
    .unwrap();
    assert_eq!(server.replicas, 3);

    let n_threads = 4usize;
    let per_thread = 12usize;
    let mut replies = Vec::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..n_threads)
            .map(|t| {
                let c = server.client().unwrap();
                s.spawn(move || {
                    (0..per_thread)
                        .map(|i| {
                            let mut img = vec![0.0f32; 8 * 8 * 3];
                            for (j, v) in img.iter_mut().enumerate() {
                                *v = ((t * 31 + i * 7 + j) % 13) as f32 / 13.0 - 0.5;
                            }
                            c.infer(img).unwrap()
                        })
                        .collect::<Vec<_>>()
                })
            })
            .collect();
        for h in handles {
            replies.extend(h.join().unwrap());
        }
    });

    let total = (n_threads * per_thread) as u64;
    assert_eq!(replies.len() as u64, total, "every request gets exactly one reply");
    for r in &replies {
        assert_eq!(r.logits.len(), 6);
        assert!(r.argmax < 6);
        assert!(r.total_ms >= 0.0);
        assert!(r.logits.iter().all(|v| v.is_finite()));
    }
    let stats = server.stats();
    server.stop();
    assert_eq!(stats.requests, total);
    assert!(stats.batches >= 1 && stats.batches <= total);
    assert!(stats.rows_dispatched >= stats.requests);
    assert!(stats.mean_occupancy() > 0.0 && stats.mean_occupancy() <= 1.0);
    std::fs::remove_dir_all(&dir).ok();
}

/// Clean shutdown with in-flight requests: submit a queue of async
/// requests, close the intake, and assert each accepted request still
/// gets exactly one reply — promptly, without the workers sitting out a
/// long `max_wait` window — and that `stop()` joins without hanging.
/// Also pins the satellite fix: `client()` after `close_intake` is a
/// typed `ServeError::Closed`, not a panic.
#[test]
fn serve_shutdown_answers_inflight_requests_without_max_wait_hang() {
    use lsqnet::serve::{Server, ServerConfig};
    let dir = tmp_dir("shutdown");
    let spec = FixtureSpec { image: 8, channels: 3, num_classes: 4, batch: 4, seed: 12 };
    let family = write_synthetic_family(&dir, "mlp", 4, spec).unwrap();
    // A deliberately huge batching window: only the disconnect/stop paths
    // can dispatch the tail batch quickly.
    let max_wait = std::time::Duration::from_secs(5);
    let mut server = Server::start(ServerConfig {
        backend: BackendSpec::native(&dir),
        family,
        checkpoint: String::new(),
        max_wait,
        queue_depth: 64,
        replicas: 2,
        intra_threads: 0,
        fused_unpack: false,
    })
    .unwrap();

    let client = server.client().unwrap();
    let n = 9usize; // not a multiple of batch: forces a partial tail batch
    let receivers: Vec<_> = (0..n)
        .map(|i| client.submit(vec![0.1 * i as f32; 8 * 8 * 3]).unwrap())
        .collect();
    let t0 = std::time::Instant::now();
    server.close_intake(); // queue disconnects; accepted requests drain

    // The old API panicked here; now it's a typed error, and live client
    // handles observe Closed on submit instead of keeping the queue open.
    assert_eq!(server.client().err(), Some(lsqnet::serve::ServeError::Closed));
    assert_eq!(
        client.submit(vec![0.3; 8 * 8 * 3]).err(),
        Some(lsqnet::serve::ServeError::Closed)
    );
    drop(client);

    let mut replies = 0usize;
    for rx in receivers {
        let rep = rx
            .recv_timeout(std::time::Duration::from_secs(30))
            .expect("request dropped without a reply")
            .expect("drained request resolves to a reply, not an error");
        assert_eq!(rep.logits.len(), 4);
        assert!(rep.logits.iter().all(|v| v.is_finite()));
        replies += 1;
    }
    assert_eq!(replies, n, "every submitted request gets exactly one reply");
    let elapsed = t0.elapsed();
    assert!(
        elapsed < max_wait,
        "shutdown waited out max_wait: {elapsed:?} (window {max_wait:?})"
    );
    let stats = server.stats();
    assert_eq!(stats.requests, n as u64);
    server.stop(); // must join promptly; hanging here fails via test timeout
    std::fs::remove_dir_all(&dir).ok();
}

/// `stop()` while caller clients are still alive must also join without
/// waiting out `max_wait`: client handles never hold the queue open, so
/// closing the intake disconnects it and the collection loop (which waits
/// in short slices) drains promptly.
#[test]
fn serve_stop_joins_while_clients_still_alive() {
    use lsqnet::serve::{Server, ServerConfig};
    let dir = tmp_dir("stopalive");
    let spec = FixtureSpec { image: 8, channels: 3, num_classes: 4, batch: 4, seed: 13 };
    let family = write_synthetic_family(&dir, "mlp", 4, spec).unwrap();
    let server = Server::start(ServerConfig {
        backend: BackendSpec::native(&dir),
        family,
        checkpoint: String::new(),
        max_wait: std::time::Duration::from_secs(5),
        queue_depth: 8,
        replicas: 2,
        intra_threads: 0,
        fused_unpack: false,
    })
    .unwrap();
    let client = server.client().unwrap(); // keeps the channel connected
    let _pending = client.submit(vec![0.2; 8 * 8 * 3]).unwrap();
    let t0 = std::time::Instant::now();
    server.stop();
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(2),
        "stop() hung on max_wait with a live client"
    );
    // The client observes the shutdown instead of hanging.
    assert!(client.infer(vec![0.2; 8 * 8 * 3]).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

/// Boundary widths of the packed-weight substrate: bits=1 and bits=8 with
/// lengths that are not a multiple of 8, exercising the tail-byte path of
/// `quantize_and_pack`/`unpack_range`.
#[test]
fn pack_boundary_bits_1_and_8_with_ragged_lengths() {
    for bits in [1u32, 8] {
        for signed in [true, false] {
            let (qn, qp) = qrange(bits, signed);
            for n in [1usize, 5, 7, 9, 15, 17, 31, 33, 63, 65] {
                let mut rng = Pcg32::seeded(900 + bits as u64 * 100 + n as u64);
                let s = 0.25f32;
                let w: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
                // quantize_and_pack always packs the signed weight grid;
                // exercise the unsigned grid through pack() directly.
                if signed {
                    let p = quantize_and_pack(&w, s, bits, true).unwrap();
                    assert_eq!(p.len, n);
                    assert_eq!(p.bytes.len(), (n * bits as usize + 7) / 8, "bits={bits} n={n}");
                    let vbar = unpack(&p);
                    for (i, &v) in w.iter().enumerate() {
                        let want = quantize_vbar(v, s, qn, qp) as i32;
                        assert_eq!(vbar[i], want, "bits={bits} n={n} i={i}");
                    }
                    // unpack_range over every suffix hits the tail byte
                    for start in [0usize, 1, n / 2, n - 1] {
                        let len = n - start;
                        let mut out = vec![0i32; len];
                        lsqnet::quant::pack::unpack_range(&p, start, len, &mut out);
                        assert_eq!(out, vbar[start..], "bits={bits} n={n} start={start}");
                    }
                } else {
                    let vals: Vec<i32> = (0..n)
                        .map(|i| ((i as i64 % (qn + qp + 1)) - qn) as i32)
                        .collect();
                    let p = lsqnet::quant::pack::pack(&vals, bits, false, s).unwrap();
                    assert_eq!(p.bytes.len(), (n * bits as usize + 7) / 8, "bits={bits} n={n}");
                    assert_eq!(unpack(&p), vals, "bits={bits} n={n}");
                }
            }
        }
    }
}

/// Rejecting a wrong-size image must not disturb the replicas.
#[test]
fn serve_rejects_bad_image_size_native() {
    use lsqnet::serve::{Server, ServerConfig};
    let dir = tmp_dir("badsize");
    let spec = FixtureSpec { image: 8, channels: 3, num_classes: 4, batch: 2, seed: 8 };
    let family = write_synthetic_family(&dir, "mlp", 8, spec).unwrap();
    let server = Server::start(ServerConfig {
        backend: BackendSpec::native(&dir),
        family,
        checkpoint: String::new(),
        max_wait: std::time::Duration::from_millis(1),
        queue_depth: 8,
        replicas: 2,
        intra_threads: 0,
        fused_unpack: false,
    })
    .unwrap();
    assert!(server.client().unwrap().submit(vec![0.0; 7]).is_err());
    // a good request still works afterwards
    let rep = server.client().unwrap().infer(vec![0.1; 8 * 8 * 3]).unwrap();
    assert_eq!(rep.logits.len(), 4);
    server.stop();
    std::fs::remove_dir_all(&dir).ok();
}
