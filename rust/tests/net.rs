//! Network serving tests: the TCP wire protocol end-to-end against real
//! sockets. Bitwise logits parity vs the engine driven directly,
//! concurrent multi-connection round-robin with exact per-variant stats,
//! structured wire errors (`unknown_model`, `bad_image`, `queue_full`
//! under saturation), `drain_and_unload` under in-flight network load
//! with zero accepted-but-unanswered requests, the `tiered` op (rejected
//! without a controller, SLO-routed with one, `shed` under ladder
//! saturation with every accepted request still answered), a slow-loris
//! dribbler tripping the total frame-assembly deadline, and a
//! protocol-robustness battery (malformed frames, split writes, oversized
//! headers, mid-request disconnects, random garbage) that must never
//! panic a replica or wedge the listener. All native + loopback — no
//! Python, no XLA, ephemeral ports only.

use std::io::{Read, Write};
use std::net::{Shutdown, TcpStream};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use lsqnet::runtime::native::fixture::{write_synthetic_family, FixtureSpec};
use lsqnet::runtime::{Backend as _, BackendSpec, PrepareOptions};
use lsqnet::serve::net::{
    frame, NetClient, NetClientError, NetRequest, NetResponse, NetServer, RespBody, WireError,
};
use lsqnet::serve::{ModelRegistry, VariantOptions};
use lsqnet::util::json::Json;

mod common;

const IMAGE_LEN: usize = 8 * 8 * 3;

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("lsq_net_{tag}_{}", std::process::id()))
}

/// Write a q2+q4 pair of the same architecture into one manifest.
fn two_tier_fixture(tag: &str, model: &str) -> (PathBuf, String, String) {
    let dir = tmp_dir(tag);
    std::fs::remove_dir_all(&dir).ok();
    let spec = FixtureSpec { image: 8, channels: 3, num_classes: 6, batch: 4, seed: 33 };
    let q2 = write_synthetic_family(&dir, model, 2, spec).unwrap();
    let q4 = write_synthetic_family(&dir, model, 4, spec).unwrap();
    (dir, q2, q4)
}

fn image(seed: usize, len: usize) -> Vec<f32> {
    (0..len).map(|j| ((seed * 31 + j * 7) % 13) as f32 / 13.0 - 0.5).collect()
}

/// Stop the server, then shut the registry down (the server joined its
/// last Arc clones, so the unwrap succeeds outside pathological races).
fn teardown(server: NetServer, registry: Arc<ModelRegistry>, dir: &PathBuf) {
    server.stop();
    if let Ok(r) = Arc::try_unwrap(registry) {
        r.shutdown();
    }
    std::fs::remove_dir_all(dir).ok();
}

fn recv_resp(s: &mut TcpStream) -> NetResponse {
    let mut buf = Vec::new();
    match frame::read_frame(s, &mut buf, frame::MAX_FRAME_LEN).unwrap() {
        frame::FrameRead::Frame => {}
        other => panic!("expected a response frame, got {other:?}"),
    }
    let text = std::str::from_utf8(&buf).unwrap();
    NetResponse::from_json(&Json::parse(text).unwrap()).unwrap()
}

/// A remote client over TCP gets bitwise-identical logits to driving the
/// `NativeEngine` directly, per variant: f32 → JSON (f64 shortest
/// round-trip text) → f32 is exact, and qgemm is bitwise deterministic,
/// so exact equality is the correct assertion even across a socket.
#[test]
fn socket_logits_bitwise_match_direct_engine() {
    let (dir, q2, q4) = two_tier_fixture("parity", "cnn_small");

    // Reference logits straight off the engine, one variant at a time.
    let mut want: Vec<Vec<Vec<f32>>> = Vec::new(); // [variant][request][logits]
    for family in [&q2, &q4] {
        let mut backend = BackendSpec::native(&dir).open().unwrap();
        let params = backend.manifest().load_initial_params(family).unwrap();
        backend.prepare_infer(family, &params, &PrepareOptions::new()).unwrap();
        let mut per_req = Vec::new();
        for i in 0..12usize {
            per_req.push(backend.infer(&image(i, IMAGE_LEN)).unwrap());
        }
        want.push(per_req);
    }

    let registry = Arc::new(ModelRegistry::open(BackendSpec::native(&dir)));
    let opts = VariantOptions {
        replicas: 2,
        max_wait: Duration::from_millis(2),
        queue_depth: 64,
        ..VariantOptions::default()
    };
    registry.load(&q2, &opts).unwrap();
    registry.load(&q4, &opts).unwrap();
    let server = NetServer::start(Arc::clone(&registry), "127.0.0.1:0").unwrap();

    let mut client = NetClient::connect(server.local_addr()).unwrap();
    client.ping().unwrap();
    assert_eq!(client.models().unwrap(), vec![q2.clone(), q4.clone()]);
    for (v, family) in [&q2, &q4].into_iter().enumerate() {
        for i in 0..12usize {
            let rep = client.infer(family, &image(i, IMAGE_LEN)).unwrap();
            assert_eq!(
                rep.logits, want[v][i],
                "variant {family} request {i}: logits over the wire diverge from \
                 the direct engine"
            );
            assert!(rep.queue_ms >= 0.0 && rep.total_ms >= 0.0);
        }
    }
    teardown(server, registry, &dir);
}

/// Four concurrent connections round-robining two variants: every reply
/// is well-formed, responses pair with their connection's requests, and
/// the per-variant server stats sum exactly to the request count.
#[test]
fn concurrent_connections_round_robin_stats_sum() {
    let (dir, q2, q4) = two_tier_fixture("rr", "mlp");
    let registry = Arc::new(ModelRegistry::open(BackendSpec::native(&dir)));
    let opts = VariantOptions {
        replicas: 2,
        max_wait: Duration::from_millis(1),
        queue_depth: 64,
        ..VariantOptions::default()
    };
    registry.load(&q2, &opts).unwrap();
    registry.load(&q4, &opts).unwrap();
    let server = NetServer::start(Arc::clone(&registry), "127.0.0.1:0").unwrap();
    let addr = server.local_addr();

    let n = 64usize;
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..4usize {
            let families = [&q2, &q4];
            handles.push(s.spawn(move || {
                let mut client = NetClient::connect(addr).unwrap();
                for i in 0..n / 4 {
                    let rep =
                        client.infer(families[i % 2], &image(t * 100 + i, IMAGE_LEN)).unwrap();
                    assert_eq!(rep.logits.len(), 6);
                    assert!(rep.logits.iter().all(|v| v.is_finite()));
                    // argmax is computed server-side; it must agree with
                    // the logits that crossed the wire. Same total order
                    // as the replica (`f32::total_cmp`, last max wins).
                    let want_argmax = rep
                        .logits
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.total_cmp(b.1))
                        .unwrap()
                        .0;
                    assert_eq!(rep.argmax, want_argmax);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    });

    let all = registry.all_stats();
    assert_eq!(all.len(), 2);
    let total: u64 = all.values().map(|s| s.requests).sum();
    assert_eq!(total, n as u64, "per-variant stats must sum to the request count");
    assert_eq!(all[&q2].requests, 32);
    assert_eq!(all[&q4].requests, 32);
    teardown(server, registry, &dir);
}

/// The structured wire errors: `unknown_model` for a bad name,
/// `bad_image` for a wrong-size image, and `queue_full{depth}` under a
/// pipelined flood against a depth-2 queue — with every flooded request
/// still answered exactly once.
#[test]
fn wire_errors_unknown_model_bad_image_and_queue_full() {
    let dir = tmp_dir("errors");
    std::fs::remove_dir_all(&dir).ok();
    let spec = FixtureSpec { image: 8, channels: 3, num_classes: 6, batch: 8, seed: 5 };
    let family = write_synthetic_family(&dir, "cnn_small", 2, spec).unwrap();
    let registry = Arc::new(ModelRegistry::open(BackendSpec::native(&dir)));
    registry
        .load(
            &family,
            &VariantOptions {
                replicas: 1,
                max_wait: Duration::from_millis(0),
                queue_depth: 2,
                ..VariantOptions::default()
            },
        )
        .unwrap();
    let server = NetServer::start(Arc::clone(&registry), "127.0.0.1:0").unwrap();
    let addr = server.local_addr();

    let mut client = NetClient::connect(addr).unwrap();
    match client.infer("nope_q9", &image(0, IMAGE_LEN)) {
        Err(NetClientError::Wire(WireError::UnknownModel { model })) => {
            assert_eq!(model, "nope_q9");
        }
        other => panic!("expected unknown_model, got {other:?}"),
    }
    match client.infer(&family, &[0.0; 7]) {
        Err(NetClientError::Wire(WireError::BadImage { got, want })) => {
            assert_eq!((got, want), (7, IMAGE_LEN));
        }
        other => panic!("expected bad_image, got {other:?}"),
    }
    // The connection is still healthy after typed errors.
    assert_eq!(client.infer(&family, &image(1, IMAGE_LEN)).unwrap().logits.len(), 6);

    // Saturation: pipeline a flood without waiting for responses. Whether
    // a given submit lands before or after the replica empties the queue
    // is timing-dependent, so retry the flood a few rounds — but each
    // round must answer *every* request, ok or error.
    let per_round = 256usize;
    let mut saw_queue_full = false;
    for round in 0..5 {
        let (mut tx, mut rx) = NetClient::connect(addr).unwrap().split().unwrap();
        let img = image(round, IMAGE_LEN);
        let fam = family.clone();
        let sender = std::thread::spawn(move || {
            for _ in 0..per_round {
                tx.send_infer(&fam, &img).unwrap();
            }
            tx.finish();
        });
        let (mut ok, mut qfull) = (0usize, 0usize);
        loop {
            match rx.recv() {
                Ok(resp) => match resp.body {
                    Ok(RespBody::Infer { logits, .. }) => {
                        assert_eq!(logits.len(), 6);
                        ok += 1;
                    }
                    Ok(other) => panic!("unexpected body {other:?}"),
                    Err(WireError::QueueFull { depth }) => {
                        assert_eq!(depth, 2, "queue_full must carry the configured depth");
                        qfull += 1;
                    }
                    Err(e) => panic!("unexpected wire error: {e}"),
                },
                Err(NetClientError::Protocol(_)) => break, // server half-closed after our EOF
                Err(e) => panic!("client error: {e}"),
            }
        }
        sender.join().unwrap();
        assert_eq!(
            ok + qfull,
            per_round,
            "round {round}: every pipelined request must get exactly one response"
        );
        if qfull > 0 {
            saw_queue_full = true;
            break;
        }
    }
    assert!(saw_queue_full, "flooding a depth-2 queue never surfaced queue_full on the wire");
    teardown(server, registry, &dir);
}

/// `drain_and_unload` under in-flight network load: every request the
/// server accepted is answered exactly once (the server-side drained
/// stats equal the clients' ok-response count), later submits get the
/// structured `closed`/`unknown_model` errors, the other variant keeps
/// serving, and no connection is wedged or dropped mid-protocol.
#[test]
fn drain_under_network_load_answers_every_accepted_request() {
    let (dir, q2, q4) = two_tier_fixture("drain", "mlp");
    let registry = Arc::new(ModelRegistry::open(BackendSpec::native(&dir)));
    let opts = VariantOptions {
        replicas: 2,
        // Deliberately huge batching window: only the drain/disconnect
        // path can dispatch the tail batch quickly.
        max_wait: Duration::from_secs(5),
        queue_depth: 128,
        ..VariantOptions::default()
    };
    registry.load(&q2, &opts).unwrap();
    registry.load(&q4, &VariantOptions::default()).unwrap();
    let server = NetServer::start(Arc::clone(&registry), "127.0.0.1:0").unwrap();
    let addr = server.local_addr();

    const CONNS: usize = 3;
    const PER_CONN: usize = 400;
    let t0 = Instant::now();
    let mut ok_total = 0usize;
    let mut err_total = 0usize;
    let mut drained_requests = 0u64;
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..CONNS {
            let q2 = &q2;
            handles.push(s.spawn(move || {
                let (mut tx, mut rx) = NetClient::connect(addr).unwrap().split().unwrap();
                // Open-loop sender on its own thread: arrival cadence must
                // not couple to response latency, or the flood would stall
                // behind the 5 s batching window instead of racing the
                // drain.
                let sender = s.spawn(move || {
                    let mut sent = 0usize;
                    for i in 0..PER_CONN {
                        if tx.send_infer(q2, &image(t * 1000 + i, IMAGE_LEN)).is_err() {
                            break;
                        }
                        sent += 1;
                    }
                    tx.finish();
                    sent
                });
                let (mut ok, mut errs) = (0usize, 0usize);
                loop {
                    match rx.recv() {
                        Ok(resp) => match resp.body {
                            Ok(RespBody::Infer { logits, .. }) => {
                                assert_eq!(logits.len(), 6);
                                ok += 1;
                            }
                            Ok(other) => panic!("unexpected body {other:?}"),
                            Err(WireError::Closed)
                            | Err(WireError::UnknownModel { .. })
                            | Err(WireError::QueueFull { .. }) => errs += 1,
                            Err(e) => panic!("unexpected wire error: {e}"),
                        },
                        Err(NetClientError::Protocol(_)) => break, // clean half-close
                        Err(e) => panic!("client error: {e}"),
                    }
                }
                let sent = sender.join().unwrap();
                assert_eq!(
                    ok + errs,
                    sent,
                    "every request sent over the wire must get exactly one response"
                );
                (ok, errs)
            }));
        }
        // Let the flood get going, then pull the tier out from under it.
        std::thread::sleep(Duration::from_millis(30));
        drained_requests = registry.drain_and_unload(&q2).unwrap().requests;
        for h in handles {
            let (ok, errs) = h.join().unwrap();
            ok_total += ok;
            err_total += errs;
        }
    });
    // Zero accepted-but-unanswered requests: the ok responses the clients
    // counted are exactly the requests the drained variant answered.
    assert_eq!(
        ok_total as u64, drained_requests,
        "accepted requests ({drained_requests}) vs ok responses ({ok_total}) diverge \
         (errors seen: {err_total})"
    );
    // Despite the 5 s max_wait, the drain dispatched the tail promptly.
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "drain under network load took {:?}",
        t0.elapsed()
    );

    // The other tier never stopped serving, over a fresh connection.
    let mut client = NetClient::connect(addr).unwrap();
    assert_eq!(client.models().unwrap(), vec![q4.clone()]);
    assert_eq!(client.infer(&q4, &image(7, IMAGE_LEN)).unwrap().logits.len(), 6);
    teardown(server, registry, &dir);
}

/// Deterministic protocol-robustness battery: malformed JSON, non-object
/// payloads, invalid UTF-8, a frame split into single-byte writes, an
/// oversized header, a truncated frame with an abrupt disconnect, and a
/// mid-request disconnect with an infer in flight. Each yields a
/// structured `bad_request`/`frame_too_large` or a clean close — and the
/// listener keeps serving afterwards.
#[test]
fn malformed_frames_split_writes_and_disconnects_never_wedge() {
    let (dir, q2, _q4) = two_tier_fixture("robust", "cnn_small");
    let registry = Arc::new(ModelRegistry::open(BackendSpec::native(&dir)));
    registry.load(&q2, &VariantOptions::default()).unwrap();
    let server = NetServer::start(Arc::clone(&registry), "127.0.0.1:0").unwrap();
    let addr = server.local_addr();

    // Malformed JSON, JSON non-objects, and invalid UTF-8 each get a
    // typed bad_request on the SAME connection, which stays usable.
    let mut s = TcpStream::connect(addr).unwrap();
    for payload in [&b"{\"id\": oops"[..], b"[1,2,3]", b"null", b"\xff\xfe\x01"] {
        frame::write_frame(&mut s, payload).unwrap();
        let resp = recv_resp(&mut s);
        assert_eq!(resp.id, Json::Null);
        assert!(
            matches!(resp.body, Err(WireError::BadRequest { .. })),
            "payload {payload:?} must yield bad_request, got {:?}",
            resp.body
        );
    }
    // A parseable request with a bad shape echoes its id.
    frame::write_frame(&mut s, b"{\"id\": 42, \"op\": \"reboot\"}").unwrap();
    let resp = recv_resp(&mut s);
    assert_eq!(resp.id.as_u64(), Some(42));
    assert!(matches!(resp.body, Err(WireError::BadRequest { .. })));

    // Same connection, now a frame dribbled in one byte at a time
    // (arbitrary TCP segmentation): still assembles into a pong.
    let ping = NetRequest::Ping { id: 7 }.to_json().to_string();
    let mut framed = Vec::new();
    frame::write_frame(&mut framed, ping.as_bytes()).unwrap();
    for b in framed {
        s.write_all(&[b]).unwrap();
        s.flush().unwrap();
        std::thread::sleep(Duration::from_millis(1));
    }
    let resp = recv_resp(&mut s);
    assert_eq!(resp.id.as_u64(), Some(7));
    assert_eq!(resp.body, Ok(RespBody::Pong));
    drop(s);

    // Oversized header: rejected before the body is read, reported as a
    // structured error, then the connection is closed by the server.
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(&u32::MAX.to_be_bytes()).unwrap();
    let resp = recv_resp(&mut s);
    match resp.body {
        Err(WireError::FrameTooLarge { len, max }) => {
            assert_eq!(len, u32::MAX as usize);
            assert_eq!(max, frame::MAX_FRAME_LEN);
        }
        other => panic!("expected frame_too_large, got {other:?}"),
    }
    let mut buf = Vec::new();
    assert!(
        matches!(frame::read_frame(&mut s, &mut buf, frame::MAX_FRAME_LEN).unwrap(),
            frame::FrameRead::Eof),
        "server must close after an unrecoverable framing error"
    );
    drop(s);

    // Truncated frame + abrupt disconnect: header promises 100 bytes,
    // 10 arrive, the client vanishes.
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(&100u32.to_be_bytes()).unwrap();
    s.write_all(&[0u8; 10]).unwrap();
    drop(s);

    // Mid-request disconnect with a real infer in flight: the reply
    // outlives the client; the writer's failed send must not wedge or
    // panic anything.
    let mut client = NetClient::connect(addr).unwrap();
    client.send_infer(&q2, &image(3, IMAGE_LEN)).unwrap();
    drop(client);

    // After the whole battery the listener still serves new connections.
    let mut client = NetClient::connect(addr).unwrap();
    client.ping().unwrap();
    assert_eq!(client.infer(&q2, &image(4, IMAGE_LEN)).unwrap().logits.len(), 6);
    drop(client);
    // And stop() completes: no wedged reader/writer threads to join.
    teardown(server, registry, &dir);
}

/// The `tiered` op end-to-end: a server started without a controller
/// rejects it with a typed `bad_request` (id echoed, connection intact);
/// a server started with one routes it to the ladder's active tier — the
/// client names no model, and the requests land on the expensive tier
/// while there is headroom.
#[test]
fn tiered_op_requires_a_controller_and_routes_when_present() {
    use lsqnet::serve::{TierConfig, TierController};
    let (dir, q2, q4) = two_tier_fixture("tiered", "cnn_small");
    let registry = Arc::new(ModelRegistry::open(BackendSpec::native(&dir)));
    let opts = VariantOptions {
        replicas: 1,
        max_wait: Duration::from_millis(1),
        queue_depth: 64,
        ..VariantOptions::default()
    };
    registry.load(&q2, &opts).unwrap();
    registry.load(&q4, &opts).unwrap();

    // Plain server: no controller, so the op is a typed bad_request.
    let server = NetServer::start(Arc::clone(&registry), "127.0.0.1:0").unwrap();
    let mut client = NetClient::connect(server.local_addr()).unwrap();
    match client.infer_tiered(&image(0, IMAGE_LEN)) {
        Err(NetClientError::Wire(WireError::BadRequest { msg })) => {
            assert!(msg.contains("tier controller"), "unhelpful rejection: {msg}");
        }
        other => panic!("expected bad_request without a controller, got {other:?}"),
    }
    // The connection survives the rejection.
    client.ping().unwrap();
    drop(client);
    server.stop();

    // Tiered server: ladder q4 (expensive) → q2 (cheap) over the same
    // registry. Requests name no model and land on the active tier.
    let ladder = vec![q4.clone(), q2.clone()];
    let ctl = Arc::new(
        TierController::new(Arc::clone(&registry), TierConfig::new(ladder, 5.0)).unwrap(),
    );
    let server =
        NetServer::start_with(Arc::clone(&registry), Some(Arc::clone(&ctl)), "127.0.0.1:0")
            .unwrap();
    let mut client = NetClient::connect(server.local_addr()).unwrap();
    let n = 12usize;
    for i in 0..n {
        let rep = client.infer_tiered(&image(i, IMAGE_LEN)).unwrap();
        assert_eq!(rep.logits.len(), 6);
        assert!(rep.logits.iter().all(|v| v.is_finite()));
    }
    // All of it went to the expensive tier (index 0, headroom untouched).
    assert_eq!(registry.stats(&q4).unwrap().requests, n as u64);
    assert_eq!(registry.stats(&q2).unwrap().requests, 0);
    assert_eq!(ctl.shed_count(), 0);
    drop(client);
    drop(ctl);
    teardown(server, registry, &dir);
}

/// Ladder saturation over the wire: flooding a tiered server whose only
/// tier has a depth-2 queue surfaces the structured `shed` error — and
/// every pipelined request still gets exactly one response.
#[test]
fn tiered_flood_sheds_on_the_wire_with_every_request_answered() {
    use lsqnet::serve::{TierConfig, TierController};
    let (dir, q2, _q4) = two_tier_fixture("shed", "cnn_small");
    let registry = Arc::new(ModelRegistry::open(BackendSpec::native(&dir)));
    registry
        .load(
            &q2,
            &VariantOptions {
                replicas: 1,
                max_wait: Duration::from_millis(0),
                queue_depth: 2,
                ..VariantOptions::default()
            },
        )
        .unwrap();
    let ctl = Arc::new(
        TierController::new(Arc::clone(&registry), TierConfig::new(vec![q2.clone()], 5.0))
            .unwrap(),
    );
    let server =
        NetServer::start_with(Arc::clone(&registry), Some(Arc::clone(&ctl)), "127.0.0.1:0")
            .unwrap();
    let addr = server.local_addr();

    // Same retry-round shape as the queue_full test: whether a given
    // submit lands before the replica drains is timing-dependent, but
    // each round must answer every request, ok or shed.
    let per_round = 256usize;
    let mut saw_shed = false;
    for round in 0..5 {
        let (mut tx, mut rx) = NetClient::connect(addr).unwrap().split().unwrap();
        let img = image(round, IMAGE_LEN);
        let sender = std::thread::spawn(move || {
            for _ in 0..per_round {
                tx.send_tiered(&img).unwrap();
            }
            tx.finish();
        });
        let (mut ok, mut shed) = (0usize, 0usize);
        loop {
            match rx.recv() {
                Ok(resp) => match resp.body {
                    Ok(RespBody::Infer { logits, .. }) => {
                        assert_eq!(logits.len(), 6);
                        ok += 1;
                    }
                    Ok(other) => panic!("unexpected body {other:?}"),
                    Err(WireError::Shed) => shed += 1,
                    Err(e) => panic!("unexpected wire error: {e}"),
                },
                Err(NetClientError::Protocol(_)) => break, // server half-closed after our EOF
                Err(e) => panic!("client error: {e}"),
            }
        }
        sender.join().unwrap();
        assert_eq!(
            ok + shed,
            per_round,
            "round {round}: every pipelined tiered request must get exactly one response"
        );
        if shed > 0 {
            saw_shed = true;
            assert_eq!(ctl.shed_count() as usize, shed, "controller shed count must match");
            break;
        }
    }
    assert!(saw_shed, "flooding a one-tier depth-2 ladder never surfaced shed on the wire");
    drop(ctl);
    teardown(server, registry, &dir);
}

/// Slow-loris defense: a client that keeps a frame alive by dribbling
/// one byte at a time gets cut off once the *total* assembly budget
/// ([`frame::MID_FRAME_DEADLINE`]) expires — per-byte progress must not
/// re-arm the deadline — and the listener serves other connections
/// throughout and after.
#[test]
fn slow_loris_dribbler_is_cut_off_and_listener_survives() {
    let (dir, q2, _q4) = two_tier_fixture("loris", "mlp");
    let registry = Arc::new(ModelRegistry::open(BackendSpec::native(&dir)));
    registry.load(&q2, &VariantOptions::default()).unwrap();
    let server = NetServer::start(Arc::clone(&registry), "127.0.0.1:0").unwrap();
    let addr = server.local_addr();

    let t0 = Instant::now();
    let mut s = TcpStream::connect(addr).unwrap();
    // Promise a 64-byte body, then dribble it slower than the budget
    // allows: 1 byte per 200 ms ≈ 13 s of dribble against a 5 s budget.
    s.write_all(&64u32.to_be_bytes()).unwrap();
    s.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
    let mut cut_off = false;
    let mut sink = [0u8; 64];
    'dribble: for _ in 0..64 {
        if s.write_all(&[0x20]).is_err() {
            cut_off = true;
            break;
        }
        s.flush().ok();
        std::thread::sleep(Duration::from_millis(200));
        // The server never answers a dribbled frame; a read returning
        // EOF means it gave up on us.
        loop {
            match s.read(&mut sink) {
                Ok(0) => {
                    cut_off = true;
                    break 'dribble;
                }
                Ok(_) => continue,
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    break
                }
                Err(_) => {
                    cut_off = true;
                    break 'dribble;
                }
            }
        }
    }
    let elapsed = t0.elapsed();
    assert!(cut_off, "a dribbled frame held the connection for the whole 64-byte body");
    assert!(
        elapsed < frame::MID_FRAME_DEADLINE * 4,
        "cut-off took {elapsed:?}, far beyond the {:?} assembly budget",
        frame::MID_FRAME_DEADLINE
    );
    drop(s);

    // A well-behaved connection opened mid-dribble-aftermath still
    // serves: one slow client never cost anyone else anything.
    let mut client = NetClient::connect(addr).unwrap();
    client.ping().unwrap();
    assert_eq!(client.infer(&q2, &image(5, IMAGE_LEN)).unwrap().logits.len(), 6);
    drop(client);
    teardown(server, registry, &dir);
}

/// Property test: connections that write random garbage — arbitrary
/// bytes, random lengths, half of them vanishing without an EOF
/// handshake — never panic a replica or wedge the listener.
#[test]
fn prop_random_garbage_frames_never_wedge_the_listener() {
    let (dir, q2, _q4) = two_tier_fixture("garbage", "mlp");
    let registry = Arc::new(ModelRegistry::open(BackendSpec::native(&dir)));
    registry.load(&q2, &VariantOptions::default()).unwrap();
    let server = NetServer::start(Arc::clone(&registry), "127.0.0.1:0").unwrap();
    let addr = server.local_addr();

    common::forall("net_garbage", 0x5eed_6000, 32, |rng| {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
        let n = rng.below(200) as usize;
        let bytes: Vec<u8> = (0..n).map(|_| rng.next_u32() as u8).collect();
        let _ = s.write_all(&bytes);
        if rng.bool(0.5) {
            // Sometimes a polite half-close, sometimes an abrupt drop.
            let _ = s.shutdown(Shutdown::Write);
        }
        // Drain whatever the server answers (bad_request frames, a
        // frame_too_large, or nothing) until EOF or timeout, then drop.
        let mut sink = [0u8; 256];
        loop {
            match s.read(&mut sink) {
                Ok(0) | Err(_) => break,
                Ok(_) => continue,
            }
        }
    });

    // Liveness after the storm: the listener accepts and serves.
    let mut client = NetClient::connect(addr).unwrap();
    client.ping().unwrap();
    assert_eq!(client.infer(&q2, &image(1, IMAGE_LEN)).unwrap().logits.len(), 6);
    drop(client);
    teardown(server, registry, &dir);
}

/// Read one request frame off a scripted fake-server stream; returns the
/// parsed request, or `None` on EOF/close.
fn read_req(s: &mut TcpStream) -> Option<NetRequest> {
    let mut buf = Vec::new();
    match frame::read_frame(s, &mut buf, frame::MAX_FRAME_LEN) {
        Ok(frame::FrameRead::Frame) => {}
        _ => return None,
    }
    let v = Json::parse(std::str::from_utf8(&buf).unwrap()).unwrap();
    NetRequest::from_json(&v).1.ok()
}

fn write_resp(s: &mut TcpStream, resp: &NetResponse) {
    frame::write_frame(s, resp.to_json().to_string().as_bytes()).unwrap();
}

/// The black-hole connect bugfix: `connect_with` returns on its timeout
/// instead of sitting in the kernel's SYN-retry schedule for minutes.
/// (10.255.255.1 is an RFC 1918 address with no host behind it; some CI
/// networks answer it with an immediate unreachable error — also fine,
/// the assertion is only that the call comes back quickly and fails.)
#[test]
fn connect_with_bounds_the_connect_against_a_black_hole() {
    let t0 = Instant::now();
    let r = NetClient::connect_with("10.255.255.1:9", Duration::from_millis(300));
    assert!(r.is_err(), "a black-holed address must not connect");
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "connect_with must return on its timeout; took {:?}",
        t0.elapsed()
    );
}

/// Transient backpressure is retried: a scripted server refuses the first
/// two attempts with `queue_full`, answers the third — the client's retry
/// loop absorbs the refusals and the caller sees one clean reply.
#[test]
fn retry_absorbs_transient_queue_full() {
    use lsqnet::serve::net::RetryPolicy;
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let mut served = 0u32;
        while let Some(req) = read_req(&mut s) {
            served += 1;
            let resp = if served <= 2 {
                NetResponse::fail(req.id(), WireError::QueueFull { depth: 2 })
            } else {
                NetResponse::ok(
                    req.id(),
                    RespBody::Infer {
                        logits: vec![0.5, 2.0],
                        argmax: 1,
                        queue_ms: 0.1,
                        total_ms: 0.2,
                    },
                )
            };
            write_resp(&mut s, &resp);
            if served == 3 {
                break;
            }
        }
        served
    });

    let mut client = NetClient::connect(addr).unwrap();
    client.set_retry(Some(RetryPolicy {
        max_attempts: 4,
        backoff: Duration::from_millis(1),
        backoff_cap: Duration::from_millis(4),
        seed: 9,
    }));
    let rep = client.infer("m_q2", &[0.5]).unwrap();
    assert_eq!(rep.argmax, 1);
    drop(client);
    assert_eq!(server.join().unwrap(), 3, "two refused attempts + one success");
}

/// Deterministic refusals are never replayed: `bad_image` fails the call
/// on the first attempt even with retries armed — the scripted server
/// must see exactly one request.
#[test]
fn retry_never_replays_deterministic_refusals() {
    use lsqnet::serve::net::RetryPolicy;
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        let req = read_req(&mut s).expect("first request");
        write_resp(&mut s, &NetResponse::fail(req.id(), WireError::BadImage { got: 1, want: 192 }));
        // Count anything the client (wrongly) sends after the refusal.
        let mut extra = 0u32;
        while read_req(&mut s).is_some() {
            extra += 1;
        }
        extra
    });

    let mut client = NetClient::connect(addr).unwrap();
    client.set_retry(Some(RetryPolicy {
        max_attempts: 4,
        backoff: Duration::from_millis(1),
        backoff_cap: Duration::from_millis(4),
        seed: 9,
    }));
    match client.infer("m_q2", &[0.5]) {
        Err(NetClientError::Wire(WireError::BadImage { got: 1, want: 192 })) => {}
        other => panic!("expected bad_image straight through, got {other:?}"),
    }
    drop(client); // EOF ends the server's counting loop
    assert_eq!(server.join().unwrap(), 0, "a deterministic refusal must not be replayed");
}

/// A connection dropped mid-request is survived transparently: the retry
/// loop reconnects and replays on a fresh socket (at-least-once — the
/// request is idempotent inference).
#[test]
fn retry_reconnects_after_a_dropped_connection() {
    use lsqnet::serve::net::RetryPolicy;
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        // Connection 1: accept the request, then vanish without a reply.
        let (mut s, _) = listener.accept().unwrap();
        let _ = read_req(&mut s).expect("first attempt arrives");
        s.shutdown(Shutdown::Both).ok();
        drop(s);
        // Connection 2: the reconnect — answer properly.
        let (mut s, _) = listener.accept().unwrap();
        let req = read_req(&mut s).expect("replayed attempt arrives on a fresh socket");
        write_resp(
            &mut s,
            &NetResponse::ok(
                req.id(),
                RespBody::Infer { logits: vec![3.0, 1.0], argmax: 0, queue_ms: 0.1, total_ms: 0.2 },
            ),
        );
    });

    let mut client = NetClient::connect(addr).unwrap();
    client.set_retry(Some(RetryPolicy {
        max_attempts: 4,
        backoff: Duration::from_millis(1),
        backoff_cap: Duration::from_millis(4),
        seed: 9,
    }));
    let rep = client.infer("m_q2", &[0.5]).unwrap();
    assert_eq!(rep.argmax, 0);
    server.join().unwrap();
}

/// `deadline_ms` end-to-end against a real server: seeded slow-exec
/// faults stretch every batch past the budget, so queued requests expire
/// and come back as structured `deadline_exceeded` — shed at dequeue,
/// never executed, never dropped.
#[test]
fn deadline_ms_sheds_queued_requests_over_the_wire() {
    use lsqnet::serve::{FaultPlan, FaultSpec};
    let (dir, q2, _q4) = two_tier_fixture("deadline", "cnn_small");
    let registry = Arc::new(ModelRegistry::open(BackendSpec::native(&dir)));
    // Every dispatched batch sleeps 50 ms; requests carry a 4 ms budget.
    let plan = Arc::new(FaultPlan::new(&FaultSpec {
        seed: 5,
        horizon: 1 << 16,
        slow_execs: 1 << 16,
        slow_exec: Duration::from_millis(50),
        ..FaultSpec::default()
    }));
    registry
        .load(
            &q2,
            &VariantOptions {
                replicas: 1,
                max_wait: Duration::from_millis(0),
                queue_depth: 64,
                fault: Some(plan),
                ..VariantOptions::default()
            },
        )
        .unwrap();
    let server = NetServer::start(Arc::clone(&registry), "127.0.0.1:0").unwrap();

    let mut client = NetClient::connect(server.local_addr()).unwrap();
    client.set_deadline_ms(Some(4));
    let n = 8usize;
    for i in 0..n {
        client.send_infer(&q2, &image(i, IMAGE_LEN)).unwrap();
    }
    let (mut ok, mut expired) = (0usize, 0usize);
    for _ in 0..n {
        match client.recv().unwrap().body {
            Ok(RespBody::Infer { logits, .. }) => {
                assert_eq!(logits.len(), 6);
                ok += 1;
            }
            Ok(other) => panic!("unexpected body {other:?}"),
            Err(WireError::DeadlineExceeded) => expired += 1,
            Err(e) => panic!("unexpected wire error: {e}"),
        }
    }
    assert_eq!(ok + expired, n, "every pipelined request gets exactly one response");
    assert!(
        expired >= 1,
        "a 4 ms budget behind 50 ms batches must expire some queued requests (ok={ok})"
    );
    let stats = registry.stats(&q2).unwrap();
    assert_eq!(stats.deadline_expired, expired as u64);
    assert_eq!(stats.answered(), n as u64);
    drop(client);
    teardown(server, registry, &dir);
}
