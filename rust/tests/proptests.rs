//! Property-based tests (in-repo mini-framework — the vendored crate
//! universe has no proptest). Each property runs over many PCG-seeded
//! random cases; failures print the offending case seed for replay.
//!
//! Coverage: quantizer algebraic invariants (Eqs. 1-3, 5), pack/unpack
//! round-trips, JSON round-trips (structure, escape sequences, the
//! adversarial nesting-depth bound), wire-protocol request/response
//! round-trips, checkpoint round-trips, dataset/batching invariants and
//! coordinator-facing schedule/metric properties.

use lsqnet::quant::lsq::*;
use lsqnet::quant::pack;
use lsqnet::util::json::Json;
use lsqnet::util::rng::Pcg32;

mod common;

const CASES: u64 = 200;

/// Run `f` over CASES seeded cases, reporting the failing seed.
fn forall(name: &str, f: impl FnMut(&mut Pcg32)) {
    common::forall(name, 0x5eed_0000, CASES, f);
}

fn rand_bits(rng: &mut Pcg32) -> (u32, bool) {
    let bits = [2u32, 3, 4, 8][rng.below(4) as usize];
    (bits, rng.bool(0.5))
}

fn rand_vals(rng: &mut Pcg32, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() * scale).collect()
}

/// Independent f64 reference for round-half-to-even (different algorithm
/// from the f32 implementation under test: floor + fractional comparison).
fn ref_round_ties_even(x: f64) -> f64 {
    let f = x.floor();
    let diff = x - f;
    if diff > 0.5 {
        f + 1.0
    } else if diff < 0.5 {
        f
    } else if (f as i64) % 2 == 0 {
        f
    } else {
        f + 1.0
    }
}

#[test]
fn prop_round_ties_even_matches_f64_reference() {
    // Random floats across magnitudes, plus a dense sweep of exact .5
    // ties — including negative ones like -2.5, where the hand-rolled
    // `(f as i64) % 2` trick must still pick the even neighbour.
    forall("round_ties_even", |rng| {
        for _ in 0..32 {
            let scale = [0.1f32, 1.0, 100.0, 1e6][rng.below(4) as usize];
            let x = rng.normal() * scale;
            let got = round_ties_even(x);
            let want = ref_round_ties_even(x as f64) as f32;
            assert_eq!(got, want, "x={x}");
        }
        // exact ties: n + 0.5 for n in [-64, 64)
        let n = rng.below(128) as i32 - 64;
        let x = n as f32 + 0.5;
        let got = round_ties_even(x);
        let want = ref_round_ties_even(x as f64) as f32;
        assert_eq!(got, want, "tie x={x}");
        assert_eq!(got as i64 % 2, 0, "tie x={x} rounded to odd {got}");
    });
}

#[test]
fn round_ties_even_negative_tie_cases() {
    // The explicit boundary cases the ISSUE calls out.
    for (x, want) in [
        (-0.5f32, 0.0f32),
        (-1.5, -2.0),
        (-2.5, -2.0),
        (-3.5, -4.0),
        (2.5, 2.0),
        (3.5, 4.0),
    ] {
        assert_eq!(round_ties_even(x), want, "x={x}");
    }
}

#[test]
fn prop_quantized_values_lie_on_grid_within_range() {
    forall("on_grid", |rng| {
        let (bits, signed) = rand_bits(rng);
        let (qn, qp) = qrange(bits, signed);
        let s = rng.range_f32(0.01, 2.0);
        for &v in &rand_vals(rng, 64, 3.0) {
            let q = quantize(v, s, qn, qp);
            let level = q / s;
            assert!((level - level.round()).abs() < 1e-4, "off-grid: {q} s={s}");
            assert!(level >= -(qn as f32) - 1e-4 && level <= qp as f32 + 1e-4);
        }
    });
}

#[test]
fn prop_quantization_is_idempotent() {
    forall("idempotent", |rng| {
        let (bits, signed) = rand_bits(rng);
        let (qn, qp) = qrange(bits, signed);
        let s = rng.range_f32(0.05, 1.0);
        for &v in &rand_vals(rng, 32, 2.0) {
            let once = quantize(v, s, qn, qp);
            let twice = quantize(once, s, qn, qp);
            assert!((once - twice).abs() < 1e-5);
        }
    });
}

#[test]
fn prop_quantize_monotone_in_v() {
    forall("monotone", |rng| {
        let (bits, signed) = rand_bits(rng);
        let (qn, qp) = qrange(bits, signed);
        let s = rng.range_f32(0.05, 1.0);
        let mut vals = rand_vals(rng, 32, 2.0);
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q: Vec<f32> = vals.iter().map(|&v| quantize(v, s, qn, qp)).collect();
        for w in q.windows(2) {
            assert!(w[1] >= w[0] - 1e-6, "non-monotone: {w:?}");
        }
    });
}

#[test]
fn prop_quantization_error_bounded_inside_domain() {
    // |vhat - v| <= s/2 for v strictly inside the clip range (Eq. 1-2).
    forall("err_bound", |rng| {
        let (bits, signed) = rand_bits(rng);
        let (qn, qp) = qrange(bits, signed);
        let s = rng.range_f32(0.05, 0.5);
        for &v in &rand_vals(rng, 64, 1.0) {
            let r = v / s;
            if r > -(qn as f32) + 0.5 && r < qp as f32 - 0.5 {
                let q = quantize(v, s, qn, qp);
                assert!((q - v).abs() <= s / 2.0 + 1e-5, "v={v} q={q} s={s}");
            }
        }
    });
}

#[test]
fn prop_grad_s_term_bounded_by_clip_levels() {
    // Eq. 3: ds is in [-max(Qn, 1/2), max(Qp, 1/2)] — inside the domain the
    // sawtooth is bounded by 1/2, saturating at -Qn / Qp outside.
    forall("ds_bounds", |rng| {
        let (bits, signed) = rand_bits(rng);
        let (qn, qp) = qrange(bits, signed);
        let s = rng.range_f32(0.05, 1.0);
        for &v in &rand_vals(rng, 64, 5.0) {
            let d = grad_s_term(v, s, qn, qp);
            // lower bound: the sawtooth reaches -1/2 inside the domain even
            // when Qn = 0 (unsigned), so the floor is -max(Qn, 1/2).
            let lo = -(qn as f32).max(0.5);
            assert!(d >= lo - 1e-5 && d <= qp as f32 + 1e-5, "d={d} lo={lo}");
            let r = v / s;
            if r > -(qn as f32) && r < qp as f32 {
                assert!(d.abs() <= 0.5 + 1e-5, "inside-domain ds {d}");
            }
        }
    });
}

#[test]
fn prop_vjp_respects_ste_masking() {
    // grad_v is exactly cot inside the domain and 0 outside (Eq. 5).
    forall("ste", |rng| {
        let (bits, signed) = rand_bits(rng);
        let (qn, qp) = qrange(bits, signed);
        let s = rng.range_f32(0.05, 0.5);
        let v = rand_vals(rng, 32, 2.0);
        let cot = rand_vals(rng, 32, 1.0);
        let (gv, _) = lsq_vjp(&v, s, qn, qp, 1.0, &cot);
        for i in 0..v.len() {
            let r = v[i] / s;
            if r > -(qn as f32) && r < qp as f32 {
                assert_eq!(gv[i], cot[i]);
            } else {
                assert_eq!(gv[i], 0.0);
            }
        }
    });
}

#[test]
fn prop_grad_scale_matches_formula() {
    forall("gscale", |rng| {
        let n = 1 + rng.below(100_000) as usize;
        let (bits, _) = rand_bits(rng);
        let (_, qp) = qrange(bits, true);
        let g = grad_scale(n, qp);
        assert!((g * ((n as f64) * qp as f64).sqrt() - 1.0).abs() < 1e-12);
    });
}

#[test]
fn prop_step_init_scales_linearly() {
    // step_init(k*v) = k * step_init(v) — homogeneity of 2<|v|>/sqrt(Qp).
    forall("step_init_homog", |rng| {
        let v = rand_vals(rng, 128, 1.0);
        let k = rng.range_f32(0.1, 10.0);
        let kv: Vec<f32> = v.iter().map(|x| x * k).collect();
        let a = step_init(&v, 3);
        let b = step_init(&kv, 3);
        assert!((b - k * a).abs() / (k * a).abs().max(1e-6) < 1e-3);
    });
}

#[test]
fn prop_pack_unpack_roundtrip() {
    forall("pack_roundtrip", |rng| {
        let (bits, signed) = rand_bits(rng);
        let (qn, qp) = qrange(bits, signed);
        let n = 1 + rng.below(300) as usize;
        let vals: Vec<i32> = (0..n)
            .map(|_| {
                let span = (qn + qp) as u32 + 1;
                rng.below(span) as i32 - qn as i32
            })
            .collect();
        let p = pack::pack(&vals, bits, signed, 0.3).unwrap();
        assert_eq!(pack::unpack(&p), vals);
        // density: exactly ceil(n*bits/8) bytes
        assert_eq!(p.bytes.len(), (n * bits as usize + 7) / 8);
    });
}

/// The precision-specialized (const-generic) unpack paths behind the
/// qgemm panel builders must agree with the scalar `unpack` reference at
/// every width, for ranges whose bit positions straddle ragged u32-word
/// boundaries — the 2/4/8-bit instances drop the byte-straddle branch at
/// compile time, and 3-bit values genuinely cross byte (and word) edges,
/// so the boundary geometry is exactly where a specialization bug would
/// hide.
#[test]
fn prop_unpack_range_spec_matches_unpack_at_ragged_boundaries() {
    forall("unpack_spec_boundaries", |rng| {
        let (bits, signed) = rand_bits(rng);
        let (qn, qp) = qrange(bits, signed);
        let n = 64 + rng.below(200) as usize;
        let span = (qn + qp) as u32 + 1;
        let vals: Vec<i32> = (0..n).map(|_| rng.below(span) as i32 - qn as i32).collect();
        let p = pack::pack(&vals, bits, signed, 0.3).unwrap();
        let full = pack::unpack(&p);

        // Starts that put the range's first bit position at / just around
        // every 32-bit word edge of the packed stream, plus random ones.
        let mut starts: Vec<usize> = Vec::new();
        for word in 0..(n * bits as usize + 31) / 32 {
            let v = (word * 32) / bits as usize;
            for s in [v.saturating_sub(1), v, v + 1] {
                if s < n {
                    starts.push(s);
                }
            }
        }
        for _ in 0..8 {
            starts.push(rng.below(n as u32) as usize);
        }
        for &start in &starts {
            let max_len = n - start;
            let len = 1 + rng.below(max_len.min(41) as u32) as usize;
            let mut got = vec![0i32; len];
            pack::unpack_range_spec(&p, start, len, &mut got);
            assert_eq!(
                got,
                full[start..start + len],
                "bits={bits} signed={signed} n={n} start={start} len={len}"
            );
        }
    });
}

#[test]
fn prop_pack_dequantize_equals_direct_quantize() {
    forall("pack_eq_quant", |rng| {
        let (bits, _) = rand_bits(rng);
        let (qn, qp) = qrange(bits, true);
        let s = rng.range_f32(0.05, 0.8);
        let w = rand_vals(rng, 100, 1.0);
        let p = pack::quantize_and_pack(&w, s, bits, true).unwrap();
        let dq = pack::dequantize(&p);
        for (a, b) in w.iter().zip(&dq) {
            assert!((quantize(*a, s, qn, qp) - b).abs() < 1e-5);
        }
    });
}

#[test]
fn prop_json_roundtrip_preserves_structure() {
    fn rand_json(rng: &mut Pcg32, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool(0.5)),
            2 => Json::Num((rng.normal() * 100.0).round() as f64 / 4.0),
            3 => Json::Str(format!("s{}_\"esc\"\n{}", rng.below(100), rng.below(10))),
            4 => Json::Arr((0..rng.below(4)).map(|_| rand_json(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), rand_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    forall("json_roundtrip", |rng| {
        let v = rand_json(rng, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(v, back, "text: {text}");
        let pretty = v.to_string_pretty();
        assert_eq!(v, Json::parse(&pretty).unwrap());
    });
}

/// Generate a string stressing every serializer escape path: quotes,
/// backslashes, named control escapes, arbitrary C0 controls (the
/// `\u00XX` path), multi-byte UTF-8 up to 4 bytes, and plain ASCII runs.
fn rand_string(rng: &mut Pcg32) -> String {
    const POOL: &[char] = &[
        '"', '\\', '/', '\n', '\t', '\r', '\u{8}', '\u{c}', '\u{1}', '\u{1f}', 'a', 'Z', '0',
        ' ', 'é', 'ß', '☃', '𝄞', '語',
    ];
    (0..rng.below(24)).map(|_| POOL[rng.below(POOL.len() as u32) as usize]).collect()
}

#[test]
fn prop_json_string_escapes_roundtrip() {
    forall("json_escapes", |rng| {
        let s = rand_string(rng);
        let v = Json::Str(s.clone());
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.as_str(), Some(s.as_str()), "text: {text}");
        // Escape-heavy strings survive as object keys too.
        let obj = Json::Obj([(s.clone(), Json::num(1.0))].into_iter().collect());
        assert_eq!(obj, Json::parse(&obj.to_string()).unwrap());
    });
}

#[test]
fn prop_json_depth_limit_boundary() {
    use lsqnet::util::json::MAX_DEPTH;
    // Random depths straddling the bound: parse succeeds iff the nesting
    // is within MAX_DEPTH, for arrays, objects, and mixes of the two.
    forall("json_depth", |rng| {
        let depth = 1 + rng.below(MAX_DEPTH as u32 + 8) as usize;
        let (mut open, mut close) = (String::new(), String::new());
        for _ in 0..depth {
            if rng.bool(0.5) {
                open.push('[');
                close.insert(0, ']');
            } else {
                open.push_str("{\"k\":");
                close.insert(0, '}');
            }
        }
        open.push('0');
        let text = format!("{open}{close}");
        assert_eq!(
            Json::parse(&text).is_ok(),
            depth <= MAX_DEPTH,
            "depth {depth} vs limit {MAX_DEPTH}"
        );
    });
}

#[test]
fn prop_wire_request_response_roundtrip() {
    use lsqnet::serve::net::{NetRequest, NetResponse, RespBody, WireError};
    fn rand_image(rng: &mut Pcg32) -> Vec<f32> {
        (0..rng.below(32))
            .map(|_| {
                let scale = [1.0f32, 1e-3, 1e6, f32::MIN_POSITIVE][rng.below(4) as usize];
                rng.normal() * scale
            })
            .collect()
    }
    forall("wire_roundtrip", |rng| {
        // Ids stay below 2^32: the wire carries them as f64 numbers, so
        // only the integer-exact range is representable (the parser
        // rejects fractional ids rather than rounding).
        let id = rng.next_u32() as u64;
        // deadline_ms is optional on the wire: absent, zero, and large
        // budgets all round-trip.
        let deadline = match rng.below(3) {
            0 => None,
            1 => Some(0u64),
            _ => Some(rng.next_u32() as u64),
        };
        let req = match rng.below(4) {
            0 => NetRequest::Infer {
                id,
                model: rand_string(rng),
                image: rand_image(rng),
                deadline_ms: deadline,
            },
            1 => NetRequest::Tiered { id, image: rand_image(rng), deadline_ms: deadline },
            2 => NetRequest::Models { id },
            _ => NetRequest::Ping { id },
        };
        let text = req.to_json().to_string();
        let (id_echo, back) = NetRequest::from_json(&Json::parse(&text).unwrap());
        assert_eq!(id_echo.as_u64(), Some(id));
        assert_eq!(back.unwrap(), req, "text: {text}");

        // Responses: every body shape and every error kind, with float
        // payloads surviving exactly (f32 → f64 text → f32 is lossless).
        let body = match rng.below(4) {
            0 => Ok(RespBody::Infer {
                logits: rand_image(rng),
                argmax: rng.below(100) as usize,
                queue_ms: rng.normal().abs() * 10.0,
                total_ms: rng.normal().abs() * 100.0,
            }),
            1 => Ok(RespBody::Models {
                models: (0..rng.below(5)).map(|_| rand_string(rng)).collect(),
            }),
            2 => Ok(RespBody::Pong),
            _ => Err(match rng.below(9) {
                0 => WireError::QueueFull { depth: rng.below(1000) as usize },
                1 => WireError::UnknownModel { model: rand_string(rng) },
                2 => WireError::Closed,
                3 => WireError::ShutDown,
                4 => WireError::Shed,
                5 => WireError::BadImage {
                    got: rng.below(1000) as usize,
                    want: rng.below(1000) as usize,
                },
                6 => WireError::BadRequest { msg: rand_string(rng) },
                7 => WireError::DeadlineExceeded,
                _ => WireError::FrameTooLarge {
                    len: rng.below(1 << 30) as usize,
                    max: 4 << 20,
                },
            }),
        };
        let resp = NetResponse { id: Json::num(id as f64), body };
        let text = resp.to_json().to_string();
        let back = NetResponse::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, resp, "text: {text}");
    });
}

#[test]
fn prop_checkpoint_roundtrip_random_tensors() {
    use lsqnet::tensor::{Checkpoint, Tensor};
    forall("ckpt_roundtrip", |rng| {
        let dir = std::env::temp_dir().join(format!(
            "lsq_pt_{}_{}",
            std::process::id(),
            rng.next_u32()
        ));
        let path = dir.join("x.ckpt");
        let mut ck = Checkpoint::new();
        let ntensors = 1 + rng.below(5) as usize;
        for i in 0..ntensors {
            let rank = rng.below(4) as usize;
            let shape: Vec<usize> = (0..rank).map(|_| 1 + rng.below(5) as usize).collect();
            let n = shape.iter().product::<usize>().max(1);
            if rng.bool(0.8) {
                ck.insert(&format!("t{i}"), Tensor::from_f32(&shape, rand_vals(rng, n, 2.0)));
            } else {
                let vals: Vec<i32> = (0..n).map(|_| rng.next_u32() as i32).collect();
                ck.insert(&format!("t{i}"), Tensor::from_i32(&shape, vals));
            }
        }
        ck.meta.insert("k".into(), Json::str("v"));
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.tensors.len(), ntensors);
        for (name, t) in &ck.tensors {
            assert_eq!(back.get(name).unwrap(), t);
        }
        std::fs::remove_dir_all(&dir).ok();
    });
}

#[test]
fn prop_eval_batches_partition_dataset() {
    use lsqnet::config::DataConfig;
    use lsqnet::data::Dataset;
    forall("batch_partition", |rng| {
        let cfg = DataConfig {
            train_size: 32 + rng.below(200) as usize,
            test_size: 1 + rng.below(200) as usize,
            classes: 2 + rng.below(8) as usize,
            noise: 0.5,
            seed: rng.next_u64(),
            augment: false,
        };
        let batch = 1 + rng.below(32) as usize;
        let ds = Dataset::test(&cfg);
        let batches = ds.eval_batches(batch);
        let total: usize = batches.iter().map(|b| b.real).sum();
        assert_eq!(total, cfg.test_size);
        for b in &batches {
            assert_eq!(b.x.shape[0], batch);
            assert!(b.real >= 1 && b.real <= batch);
            // labels in range
            for &y in b.y.i32s().unwrap() {
                assert!((y as usize) < cfg.classes);
            }
        }
    });
}

#[test]
fn prop_lr_schedules_nonnegative_and_bounded() {
    use lsqnet::config::{Schedule, TrainConfig};
    use lsqnet::train::lr::lr_at;
    forall("lr_bounds", |rng| {
        let cfg = TrainConfig {
            epochs: 1 + rng.below(50) as usize,
            lr: rng.range_f32(1e-4, 1.0) as f64,
            schedule: [Schedule::Cosine, Schedule::Step, Schedule::Const][rng.below(3) as usize],
            step_every: 1 + rng.below(10) as usize,
            ..Default::default()
        };
        let spe = 1 + rng.below(50) as usize;
        let total = cfg.epochs * spe;
        for step in (0..total).step_by((total / 17).max(1)) {
            let v = lr_at(&cfg, spe, step);
            assert!(v >= 0.0 && v <= cfg.lr + 1e-12, "lr {v} base {}", cfg.lr);
        }
    });
}

#[test]
fn prop_topk_monotone_in_k() {
    use lsqnet::train::metrics::topk_correct;
    forall("topk_monotone", |rng| {
        let rows = 1 + rng.below(16) as usize;
        let classes = 2 + rng.below(10) as usize;
        let logits = rand_vals(rng, rows * classes, 1.0);
        let labels: Vec<i32> = (0..rows).map(|_| rng.below(classes as u32) as i32).collect();
        let mut prev = 0;
        for k in 1..=classes {
            let c = topk_correct(&logits, &labels, classes, k, rows);
            assert!(c >= prev && c <= rows);
            prev = c;
        }
        assert_eq!(prev, rows, "top-#classes must be everything");
    });
}

#[test]
fn prop_model_size_monotone_in_bits() {
    use lsqnet::quant::model_size::{model_bytes, LayerMeta};
    forall("size_monotone", |rng| {
        let nl = 1 + rng.below(8) as usize;
        let weights: Vec<usize> = (0..nl).map(|_| 16 + rng.below(5000) as usize).collect();
        let mut prev = 0usize;
        for bits in [2u32, 3, 4, 8] {
            let layers: Vec<LayerMeta> = weights
                .iter()
                .enumerate()
                .map(|(i, &n)| LayerMeta { name: format!("l{i}"), n_weights: n, bits })
                .collect();
            let b = model_bytes(&layers);
            assert!(b >= prev);
            prev = b;
        }
    });
}

#[test]
fn prop_augment_preserves_pixel_multiset_bounds() {
    // Augmented images only contain pixels from the original (plus zero
    // padding) — crop+mirror never invents values.
    use lsqnet::data::augment::augment;
    use lsqnet::data::SynthSpec;
    forall("augment_values", |rng| {
        let spec = SynthSpec::new(4, 0.8, rng.next_u64());
        let orig = spec.generate_alloc(rng.below(1000) as usize);
        let mut img = orig.clone();
        let mut scratch = Vec::new();
        augment(&mut img, &mut scratch, rng);
        let mut allowed: Vec<u32> = orig.iter().map(|f| f.to_bits()).collect();
        allowed.push(0.0f32.to_bits());
        allowed.sort_unstable();
        for px in &img {
            assert!(
                allowed.binary_search(&px.to_bits()).is_ok(),
                "augment invented pixel {px}"
            );
        }
    });
}
