//! Multi-model registry tests: named sessions against several precision
//! variants in one process, per-variant stats, logits parity vs the
//! engine driven directly, hot load/unload under in-flight traffic, and
//! the typed `ServeError` surface. All native — the synthetic fixture
//! provides the manifest + params, so no Python/XLA is needed.

use std::path::PathBuf;
use std::time::Duration;

use lsqnet::runtime::native::fixture::{write_synthetic_family, FixtureSpec};
use lsqnet::runtime::{Backend as _, BackendSpec, PrepareOptions};
use lsqnet::serve::{ModelRegistry, ServeError, VariantOptions};

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("lsq_registry_{tag}_{}", std::process::id()))
}

/// Write a q2+q4 pair of the same architecture into one manifest.
fn two_tier_fixture(tag: &str, model: &str) -> (PathBuf, String, String) {
    let dir = tmp_dir(tag);
    std::fs::remove_dir_all(&dir).ok();
    let spec = FixtureSpec { image: 8, channels: 3, num_classes: 6, batch: 4, seed: 33 };
    let q2 = write_synthetic_family(&dir, model, 2, spec).unwrap();
    let q4 = write_synthetic_family(&dir, model, 4, spec).unwrap();
    (dir, q2, q4)
}

fn image(seed: usize, len: usize) -> Vec<f32> {
    (0..len).map(|j| ((seed * 31 + j * 7) % 13) as f32 / 13.0 - 0.5).collect()
}

/// Concurrent sessions against two precision variants of one architecture
/// produce exactly the logits the engine computes when driven directly —
/// per variant, even when the traffic interleaves (qgemm is bitwise
/// deterministic across batch shapes and thread counts, so exact equality
/// is the correct assertion).
#[test]
fn concurrent_sessions_match_direct_engine_per_variant() {
    let (dir, q2, q4) = two_tier_fixture("parity", "cnn_small");
    let image_len = 8 * 8 * 3;

    // Reference logits straight off the engine, one variant at a time.
    let mut want: Vec<Vec<Vec<f32>>> = Vec::new(); // [variant][request][logits]
    for family in [&q2, &q4] {
        let mut backend = BackendSpec::native(&dir).open().unwrap();
        let params = backend.manifest().load_initial_params(family).unwrap();
        backend.prepare_infer(family, &params, &PrepareOptions::new()).unwrap();
        let mut per_req = Vec::new();
        for i in 0..12usize {
            per_req.push(backend.infer(&image(i, image_len)).unwrap());
        }
        want.push(per_req);
    }

    let registry = ModelRegistry::open(BackendSpec::native(&dir));
    let opts = VariantOptions {
        replicas: 2,
        max_wait: Duration::from_millis(2),
        queue_depth: 64,
        ..VariantOptions::default()
    };
    registry.load(&q2, &opts).unwrap();
    registry.load(&q4, &opts).unwrap();
    assert_eq!(registry.variants(), vec![q2.clone(), q4.clone()]);
    assert_eq!(registry.total_replicas(), 4);

    // Two client threads per variant, interleaved traffic.
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (v, family) in [&q2, &q4].into_iter().enumerate() {
            for half in 0..2usize {
                let session = registry.session(family).unwrap();
                let want = &want;
                handles.push(s.spawn(move || {
                    for i in (half * 6)..(half * 6 + 6) {
                        let rep = session.infer(image(i, image_len)).unwrap();
                        assert_eq!(
                            rep.logits, want[v][i],
                            "variant {} request {i}: batched serve logits diverge \
                             from the direct engine",
                            session.variant()
                        );
                    }
                }));
            }
        }
        for h in handles {
            h.join().unwrap();
        }
    });

    // Per-variant stats: each tier answered exactly its own 12 requests.
    for family in [&q2, &q4] {
        let stats = registry.stats(family).unwrap();
        assert_eq!(stats.requests, 12, "{family}");
        assert!(stats.batches >= 1 && stats.batches <= 12, "{family}");
        assert!(stats.mean_occupancy() > 0.0 && stats.mean_occupancy() <= 1.0);
        assert!(stats.mean_queue_ms() >= 0.0);
        // The native backend never pads.
        assert_eq!(stats.padding_rows, 0, "{family}");
        assert_eq!(stats.rows_dispatched, stats.requests);
    }
    registry.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// The ci.sh gateway stage: a two-variant native registry (q2+q4
/// synthetic fixture), 64 requests round-robined across both sessions,
/// per-variant stats summing exactly to the request count.
#[test]
fn round_robin_64_requests_per_variant_stats_sum() {
    let (dir, q2, q4) = two_tier_fixture("rr64", "mlp");
    let image_len = 8 * 8 * 3;
    let registry = ModelRegistry::open(BackendSpec::native(&dir));
    let opts = VariantOptions {
        replicas: 2,
        max_wait: Duration::from_millis(1),
        queue_depth: 64,
        ..VariantOptions::default()
    };
    registry.load(&q2, &opts).unwrap();
    registry.load(&q4, &opts).unwrap();

    let n = 64usize;
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..4usize {
            let sessions = [registry.session(&q2).unwrap(), registry.session(&q4).unwrap()];
            handles.push(s.spawn(move || {
                for i in 0..n / 4 {
                    let rep = sessions[i % 2].infer(image(t * 100 + i, image_len)).unwrap();
                    assert_eq!(rep.logits.len(), 6);
                    assert!(rep.logits.iter().all(|v| v.is_finite()));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    });

    let all = registry.all_stats();
    assert_eq!(all.len(), 2);
    let total: u64 = all.values().map(|s| s.requests).sum();
    assert_eq!(total, n as u64, "per-variant stats must sum to the request count");
    assert_eq!(all[&q2].requests, 32);
    assert_eq!(all[&q4].requests, 32);
    registry.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Hot-unload under in-flight load: client threads hammer variant A while
/// it is drained; every request accepted before the drain is answered
/// exactly once, submits after it fail with the typed `Closed`/`ShutDown`
/// errors, and variant B keeps serving throughout and afterwards.
#[test]
fn hot_unload_answers_every_accepted_request_exactly_once() {
    let (dir, q2, q4) = two_tier_fixture("unload", "mlp");
    let image_len = 8 * 8 * 3;
    let registry = ModelRegistry::open(BackendSpec::native(&dir));
    let opts = VariantOptions {
        replicas: 2,
        // Deliberately huge batching window: only the drain/disconnect
        // path can dispatch the tail batch quickly.
        max_wait: Duration::from_secs(5),
        queue_depth: 128,
        ..VariantOptions::default()
    };
    registry.load(&q2, &opts).unwrap();
    registry.load(&q4, &opts).unwrap();

    let t0 = std::time::Instant::now();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..3usize {
            let session = registry.session(&q2).unwrap();
            handles.push(s.spawn(move || {
                let mut pending: Vec<std::sync::mpsc::Receiver<_>> = Vec::new();
                let mut accepted = 0usize;
                let mut closed = 0usize;
                for i in 0..400usize {
                    match session.submit(image(t * 1000 + i, image_len)) {
                        Ok(rx) => {
                            accepted += 1;
                            pending.push(rx);
                        }
                        Err(ServeError::Closed) | Err(ServeError::ShutDown) => {
                            closed += 1;
                            if closed > 3 {
                                break; // variant is gone; stop hammering
                            }
                        }
                        Err(ServeError::QueueFull { .. }) => {
                            // Backpressure under the flood: drain one reply
                            // (it stays counted as accepted) and continue.
                            if let Some(rx) = pending.pop() {
                                rx.recv().expect("accepted request must be answered");
                            }
                        }
                        Err(e) => panic!("unexpected serve error: {e}"),
                    }
                }
                // Every accepted request gets exactly one reply, even the
                // ones that were still queued when the drain started.
                for rx in pending {
                    let rep = rx
                        .recv_timeout(Duration::from_secs(30))
                        .expect("accepted request dropped without a reply")
                        .expect("drained request must be executed, not refused");
                    assert_eq!(rep.logits.len(), 6);
                }
                accepted
            }));
        }
        // Let the clients get going, then pull the tier out from under them.
        std::thread::sleep(Duration::from_millis(20));
        let drained = registry.drain_and_unload(&q2).unwrap();
        // The variant is gone from the registry the moment drain returns.
        assert!(matches!(registry.session(&q2), Err(ServeError::UnknownModel(_))));
        assert!(matches!(registry.stats(&q2), Err(ServeError::UnknownModel(_))));

        let accepted_total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
        // Exactly once: the drain's final stats count every accepted
        // request (replies were asserted above), no more, no fewer.
        assert_eq!(drained.requests as usize, accepted_total);
    });
    // Despite the 5s max_wait, the drain never sat out the batching window.
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "drain_and_unload waited out max_wait: {:?}",
        t0.elapsed()
    );

    // The other tier never stopped serving.
    let s4 = registry.session(&q4).unwrap();
    let rep = s4.infer(image(7, image_len)).unwrap();
    assert_eq!(rep.logits.len(), 6);
    assert_eq!(registry.variants(), vec![q4.clone()]);

    // Hot *re*-load: the drained name can come back (e.g. a re-trained
    // checkpoint) while B still serves.
    registry.load(&q2, &VariantOptions::default()).unwrap();
    let s2 = registry.session(&q2).unwrap();
    assert_eq!(s2.infer(image(9, image_len)).unwrap().logits.len(), 6);
    registry.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// `QueueFull { depth }` surfaces at the configured bound instead of
/// blocking forever: flood a single-replica variant without consuming
/// replies; during any exec window the queue must hit its depth-2 cap.
#[test]
fn queue_full_surfaces_at_queue_depth() {
    let dir = tmp_dir("qfull");
    std::fs::remove_dir_all(&dir).ok();
    let spec = FixtureSpec { image: 8, channels: 3, num_classes: 6, batch: 8, seed: 5 };
    let family = write_synthetic_family(&dir, "cnn_small", 2, spec).unwrap();
    let image_len = 8 * 8 * 3;
    let registry = ModelRegistry::open(BackendSpec::native(&dir));
    registry
        .load(
            &family,
            &VariantOptions {
                replicas: 1,
                max_wait: Duration::from_millis(0),
                queue_depth: 2,
                ..VariantOptions::default()
            },
        )
        .unwrap();
    let session = registry.session(&family).unwrap();

    let mut receivers = Vec::new();
    let mut hit = None;
    for i in 0..5_000usize {
        match session.submit(image(i, image_len)) {
            Ok(rx) => receivers.push(rx),
            Err(ServeError::QueueFull { depth }) => {
                hit = Some(depth);
                break;
            }
            Err(e) => panic!("unexpected serve error: {e}"),
        }
    }
    assert_eq!(hit, Some(2), "submit must surface QueueFull at the configured depth");
    // Backpressure is non-destructive: everything accepted is answered.
    for rx in receivers {
        rx.recv_timeout(Duration::from_secs(30))
            .expect("accepted request must still be answered after backpressure");
    }
    registry.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// The typed error surface: unknown variants, duplicate loads, and
/// drained variants each produce their distinct error.
#[test]
fn typed_errors_unknown_duplicate_and_closed() {
    let dir = tmp_dir("errors");
    std::fs::remove_dir_all(&dir).ok();
    let spec = FixtureSpec { image: 8, channels: 3, num_classes: 4, batch: 4, seed: 11 };
    let family = write_synthetic_family(&dir, "mlp", 4, spec).unwrap();
    let image_len = 8 * 8 * 3;
    let registry = ModelRegistry::open(BackendSpec::native(&dir));

    assert_eq!(
        registry.session("nope_q2").err(),
        Some(ServeError::UnknownModel("nope_q2".to_string()))
    );
    registry.load(&family, &VariantOptions::default()).unwrap();
    // Loading a live name twice is an error (drain first), and a family
    // the manifest doesn't know fails synchronously.
    assert!(registry.load(&family, &VariantOptions::default()).is_err());
    assert!(registry.load("missing_q3", &VariantOptions::default()).is_err());

    let session = registry.session(&family).unwrap();
    assert_eq!(
        session.submit(vec![0.0; 7]).err(),
        Some(ServeError::BadImage { got: 7, want: image_len })
    );
    // close_intake: sessions observe Closed, stats stay readable.
    registry.close_intake(&family).unwrap();
    assert!(!session.is_open());
    assert_eq!(session.submit(image(0, image_len)).err(), Some(ServeError::Closed));
    assert!(registry.stats(&family).is_ok());
    let stats = registry.drain_and_unload(&family).unwrap();
    assert_eq!(stats.requests, 0);
    registry.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// The core budget partitions across every replica at load time and the
/// load-time options flow through PrepareOptions (panelized vs fused
/// low-memory bind both serve identical logits).
#[test]
fn core_budget_and_low_memory_options() {
    let (dir, q2, q4) = two_tier_fixture("budget", "cnn_small");
    let image_len = 8 * 8 * 3;
    let registry = ModelRegistry::with_core_budget(BackendSpec::native(&dir), 8);
    assert_eq!(registry.core_budget(), 8);
    registry
        .load(&q2, &VariantOptions { replicas: 2, ..VariantOptions::default() })
        .unwrap();
    registry
        .load(
            &q4,
            &VariantOptions {
                replicas: 2,
                low_memory: Some(true), // fused weights for this tier only
                ..VariantOptions::default()
            },
        )
        .unwrap();
    let lo = registry.session(&q2).unwrap().infer(image(3, image_len)).unwrap();
    let hi = registry.session(&q4).unwrap().infer(image(3, image_len)).unwrap();
    assert_eq!(lo.logits.len(), 6);
    assert_eq!(hi.logits.len(), 6);

    // Fused and panelized binds are bitwise-identical datapaths: serve the
    // same variant twice (fresh registry), once per mode, same input.
    registry.shutdown();
    for low_memory in [Some(false), Some(true)] {
        let r = ModelRegistry::open(BackendSpec::native(&dir));
        r.load(&q2, &VariantOptions { low_memory, ..VariantOptions::default() }).unwrap();
        let rep = r.session(&q2).unwrap().infer(image(3, image_len)).unwrap();
        assert_eq!(rep.logits, lo.logits, "low_memory={low_memory:?}");
        r.shutdown();
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The supervisor in its healthy steady state: seeded faults panic
/// replicas mid-traffic, every panicked replica is respawned (counted in
/// `replica_restarts`), the variant converges back to its full replica
/// count, and no accepted request is ever dropped — panics answer their
/// batch with a typed error before dying.
#[test]
fn supervisor_respawns_panicked_replicas_and_recovers() {
    use lsqnet::serve::{FaultPlan, FaultSpec, RestartPolicy};
    use std::sync::Arc;
    let dir = tmp_dir("respawn");
    std::fs::remove_dir_all(&dir).ok();
    let spec = FixtureSpec { image: 8, channels: 3, num_classes: 6, batch: 4, seed: 33 };
    let family = write_synthetic_family(&dir, "cnn_small", 2, spec).unwrap();
    let image_len = 8 * 8 * 3;
    let registry = ModelRegistry::open(BackendSpec::native(&dir));
    let plan = Arc::new(FaultPlan::new(&FaultSpec {
        seed: 7,
        horizon: 200,
        replica_panics: 3,
        ..FaultSpec::default()
    }));
    registry
        .load(
            &family,
            &VariantOptions {
                replicas: 2,
                max_wait: Duration::from_millis(1),
                queue_depth: 64,
                fault: Some(Arc::clone(&plan)),
                restarts: RestartPolicy {
                    budget: 8,
                    window: Duration::from_secs(60),
                    backoff: Duration::from_millis(1),
                    backoff_cap: Duration::from_millis(5),
                    jitter_seed: 0,
                },
                ..VariantOptions::default()
            },
        )
        .unwrap();
    let session = registry.session(&family).unwrap();

    // Sequential traffic: each infer dispatches one batch, so the exec
    // fault site advances once per request and all 3 panics fire within
    // 200 requests. A panicked batch answers with a typed error — the
    // infer returns Err, never hangs.
    let mut ok = 0u64;
    let mut errs = 0u64;
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    let mut i = 0usize;
    while !plan.all_fired() {
        assert!(
            std::time::Instant::now() < deadline,
            "fault plan never drained; fired so far: {:?}",
            plan.fired()
        );
        match session.infer(image(i, image_len)) {
            Ok(rep) => {
                assert_eq!(rep.logits.len(), 6);
                ok += 1;
            }
            Err(_) => errs += 1,
        }
        i += 1;
    }
    assert_eq!(errs, 3, "each planned panic fails exactly its own one-request batch");

    // Convergence: the supervisor returns the variant to full strength.
    // Poll the restart counter too — it is bumped adjacent to (not
    // atomically with) the respawned thread's liveness increment.
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while registry.live_replicas(&family).unwrap() < 2
        || registry.stats(&family).unwrap().replica_restarts < 3
    {
        assert!(std::time::Instant::now() < deadline, "replica count never reconverged");
        std::thread::sleep(Duration::from_millis(2));
    }
    assert_eq!(registry.healthy(&family), Ok(true));
    let stats = registry.stats(&family).unwrap();
    assert_eq!(stats.replica_failures, 3);
    assert_eq!(stats.replica_restarts, 3);
    // The exactly-once ledger covers the whole run: every accepted
    // request resolved as a reply or a typed error.
    assert_eq!(stats.answered(), ok + errs);

    // Post-recovery traffic flows normally.
    assert_eq!(session.infer(image(9999, image_len)).unwrap().logits.len(), 6);
    registry.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

/// Drain racing an in-flight respawn: the only replica panics, the
/// supervisor owes a respawn with a long backoff, and `drain_and_unload`
/// lands inside that window. The drain must cancel the respawn (no
/// restart counted), spin up a teardown drainer instead, and still answer
/// every accepted request exactly once.
#[test]
fn drain_during_in_flight_respawn_answers_every_accepted_request() {
    use lsqnet::serve::{FaultPlan, FaultSpec, RestartPolicy};
    use std::sync::Arc;
    let dir = tmp_dir("drainrespawn");
    std::fs::remove_dir_all(&dir).ok();
    let spec = FixtureSpec { image: 8, channels: 3, num_classes: 6, batch: 4, seed: 33 };
    let family = write_synthetic_family(&dir, "mlp", 2, spec).unwrap();
    let image_len = 8 * 8 * 3;
    let registry = ModelRegistry::open(BackendSpec::native(&dir));
    // Exactly one panic, on the first dispatched batch.
    let plan = Arc::new(FaultPlan::new(&FaultSpec {
        seed: 3,
        horizon: 1,
        replica_panics: 1,
        ..FaultSpec::default()
    }));
    registry
        .load(
            &family,
            &VariantOptions {
                replicas: 1,
                max_wait: Duration::from_millis(1),
                queue_depth: 64,
                fault: Some(Arc::clone(&plan)),
                // Long backoff: the respawn is still pending when the
                // drain arrives (submits below take microseconds).
                restarts: RestartPolicy {
                    budget: 4,
                    window: Duration::from_secs(60),
                    backoff: Duration::from_millis(250),
                    backoff_cap: Duration::from_millis(250),
                    jitter_seed: 0,
                },
                ..VariantOptions::default()
            },
        )
        .unwrap();
    let session = registry.session(&family).unwrap();

    // Trigger the panic and wait for its (typed-error) answer: the sole
    // replica is now dead, the respawn is due in ~250 ms.
    let rx = session.submit(image(0, image_len)).unwrap();
    assert!(
        rx.recv_timeout(Duration::from_secs(10)).expect("panicked batch must be answered").is_err(),
        "the panicked batch answers with a typed error"
    );

    // Requests accepted while zero replicas are live: they sit in the
    // queue owned by the variant, not by any dead thread.
    let mut pending = Vec::new();
    for i in 1..=16usize {
        pending.push(session.submit(image(i, image_len)).unwrap());
    }

    // Drain inside the respawn window. It must not race the respawn —
    // the supervisor cancels it and runs teardown drainers instead.
    let drained = registry.drain_and_unload(&family).unwrap();

    // Every accepted request is answered exactly once. The queued ones
    // are *executed* by the drainer (the single planned panic already
    // fired), not refused.
    for rx in pending {
        let rep = rx
            .recv_timeout(Duration::from_secs(10))
            .expect("request accepted before the drain was dropped")
            .expect("queued requests are executed by the teardown drainer");
        assert_eq!(rep.logits.len(), 6);
        assert!(rx.try_recv().is_err(), "request answered twice");
    }
    // Ledger: 1 panicked + 16 drained = 17 answered; the canceled
    // respawn is not a restart.
    assert_eq!(drained.answered(), 17);
    assert_eq!(drained.replica_failures, 1);
    assert_eq!(drained.replica_restarts, 0);
    assert_eq!(drained.requests, 16);
    assert_eq!(drained.failed_requests, 1);
    registry.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}
