//! Integration tests for the SLO tier controller (DESIGN.md
//! §Serving-API): the exact transition sequence under a deterministic
//! burst/ramp/sine traffic schedule, the accepted-implies-answered
//! guarantee under ladder routing, explicit shedding at saturation,
//! drain failover, and the `BENCH_serve.json` decision trace.
//!
//! The schedule test separates act from decide on purpose: real requests
//! flow through `TierController::route` every epoch (so the drain
//! guarantee is exercised on whichever tier the controller currently
//! favors), while the decisions are driven by `step_with` on synthetic
//! signals — a pure function of the schedule, so the expected transition
//! sequence is exact, not statistical.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use lsqnet::runtime::native::fixture::{write_synthetic_family, FixtureSpec};
use lsqnet::runtime::BackendSpec;
use lsqnet::serve::tier::trace_to_bench;
use lsqnet::serve::{
    ModelRegistry, ServeError, TierConfig, TierController, TierDecision, TierSignal,
    VariantOptions,
};
use lsqnet::util::bench::{Bench, BenchOpts};

/// 8x8x3 fixture geometry (same scale as tests/net.rs: small enough that
/// a full schedule of real requests stays fast).
const IMAGE_LEN: usize = 8 * 8 * 3;
const CLASSES: usize = 6;

/// Write a three-precision ladder (q8 → q4 → q2) of the synthetic
/// `cnn_small` family into a fresh temp dir; returns (dir, family names
/// expensive-first).
fn ladder_fixture(tag: &str) -> (PathBuf, Vec<String>) {
    let dir = std::env::temp_dir().join(format!("lsq_tier_{tag}_{}", std::process::id()));
    let spec = FixtureSpec { image: 8, channels: 3, num_classes: CLASSES, batch: 4, seed: 33 };
    let fams = [8u32, 4, 2]
        .iter()
        .map(|&bits| write_synthetic_family(&dir, "cnn_small", bits, spec).expect("fixture"))
        .collect();
    (dir, fams)
}

fn image(seed: usize) -> Vec<f32> {
    (0..IMAGE_LEN).map(|i| ((seed * 31 + i * 7) % 17) as f32 * 0.1 - 0.8).collect()
}

fn opts(queue_depth: usize) -> VariantOptions {
    VariantOptions {
        replicas: 1,
        max_wait: Duration::from_millis(1),
        queue_depth,
        ..VariantOptions::default()
    }
}

/// SLO 8 ms, defaults otherwise (breach after 2 epochs, recover below
/// 4 ms after 3); window 1 so synthetic signals pass through unsmoothed.
fn cfg_for(fams: &[String]) -> TierConfig {
    let mut cfg = TierConfig::new(fams.to_vec(), 8.0);
    cfg.window = 1;
    cfg
}

/// A linear load model: tier capacities 4/8/16 (cheaper = more capacity),
/// queue time 2·offered/capacity — so the same offered load senses as
/// progressively lighter further down the ladder.
const CAPS: [f64; 3] = [4.0, 8.0, 16.0];

fn signals(offered: f64) -> Vec<TierSignal> {
    CAPS.iter()
        .map(|cap| TierSignal {
            queue_ms: 2.0 * offered / cap,
            depth: offered as usize,
            occupancy: 1.0,
            healthy: true,
        })
        .collect()
}

/// The tentpole acceptance test: a deterministic burst → ramp → sine
/// schedule produces an exact, hand-traceable transition sequence; every
/// accepted request is answered exactly once; the decision trace lands in
/// BENCH_serve.json.
#[test]
fn deterministic_schedule_produces_exact_transition_sequence() {
    let (dir, fams) = ladder_fixture("sched");
    let registry = Arc::new(ModelRegistry::open(BackendSpec::native(&dir)));
    for f in &fams {
        registry.load(f, &opts(64)).unwrap();
    }
    let ctl = TierController::new(Arc::clone(&registry), cfg_for(&fams)).unwrap();

    // Offered load per epoch. With SLO 8 ms and the CAPS load model:
    // tier 0 breaches above 16 offered, recovers below 8; tier 1
    // breaches above 32, recovers below 16; tier 2 recovers below 32.
    #[rustfmt::skip]
    let schedule: Vec<f64> = vec![
        2.0, 2.0, 24.0, 24.0, 24.0, 2.0, 2.0,                      // burst
        4.0, 8.0, 12.0, 16.0, 20.0, 24.0, 28.0, 32.0, 36.0, 40.0,  // ramp
        12.0, 8.0, 20.0, 12.0, 8.0, 4.0, 4.0, 4.0,                 // sine-ish
    ];

    let mut accepted = 0usize;
    let mut answered = 0usize;
    for (k, &offered) in schedule.iter().enumerate() {
        // Act: real traffic through the ladder at this epoch's offered
        // load, routed to whichever tier the controller currently favors.
        let mut pending = Vec::new();
        for i in 0..offered as usize {
            match ctl.route(image(1000 * k + i)) {
                Ok(rx) => {
                    accepted += 1;
                    pending.push(rx);
                }
                Err(e) => panic!("epoch {k} request {i} refused: {e}"),
            }
        }
        for rx in pending {
            let reply = rx
                .recv()
                .expect("accepted request must be answered")
                .expect("healthy ladder answers with a reply, not an error");
            assert_eq!(reply.logits.len(), CLASSES);
            answered += 1;
            // Exactly once: the reply channel never yields a second answer.
            assert!(rx.try_recv().is_err(), "request answered twice");
        }
        // Decide: one pure hysteresis step on the synthetic signals.
        ctl.step_with(&signals(offered));
    }
    assert_eq!(accepted, answered, "an accepted request was dropped");
    assert_eq!(accepted, schedule.iter().map(|&o| o as usize).sum::<usize>());
    assert_eq!(ctl.shed_count(), 0);
    assert_eq!(ctl.epochs(), schedule.len() as u64);

    // The exact transition sequence (epoch, from, to, reason): burst
    // down+up, ramp down twice, sine decay back up twice.
    let trace = ctl.trace();
    let got: Vec<(u64, usize, usize, &str)> =
        trace.iter().map(|e| (e.epoch, e.from, e.to, e.reason)).collect();
    assert_eq!(
        got,
        vec![
            (4, 0, 1, "slo_breach"),
            (8, 1, 0, "headroom"),
            (13, 0, 1, "slo_breach"),
            (17, 1, 2, "slo_breach"),
            (20, 2, 1, "headroom"),
            (23, 1, 0, "headroom"),
        ]
    );
    assert_eq!(ctl.active_tier(), 0, "sine decay must return the ladder to the top");

    // The decision trace is emitted as BENCH_serve.json rows and survives
    // a parse round-trip.
    let mut b = Bench::with_opts(
        "serve",
        BenchOpts {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(1),
            min_iters: 1,
        },
    );
    trace_to_bench(&mut b, ctl.tiers(), &trace);
    let path = dir.join("BENCH_serve.json");
    b.write_json(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let expect = [
        format!("tier_shift_e4_slo_breach_{}_to_{}", fams[0], fams[1]),
        format!("tier_shift_e8_headroom_{}_to_{}", fams[1], fams[0]),
        format!("tier_shift_e13_slo_breach_{}_to_{}", fams[0], fams[1]),
        format!("tier_shift_e17_slo_breach_{}_to_{}", fams[1], fams[2]),
        format!("tier_shift_e20_headroom_{}_to_{}", fams[2], fams[1]),
        format!("tier_shift_e23_headroom_{}_to_{}", fams[1], fams[0]),
    ];
    for name in &expect {
        assert!(text.contains(name.as_str()), "missing {name} in BENCH_serve.json");
    }
    assert_eq!(text.matches("tier_shift_e").count(), expect.len());

    drop(ctl);
    if let Ok(r) = Arc::try_unwrap(registry) {
        r.shutdown();
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Once the cheapest tier's queue is full, `route` sheds explicitly
/// (counted, typed) instead of queueing without bound — and every request
/// that *was* accepted is still answered.
#[test]
fn ladder_saturation_sheds_instead_of_queueing() {
    let (dir, fams) = ladder_fixture("shed");
    let registry = Arc::new(ModelRegistry::open(BackendSpec::native(&dir)));
    // A one-tier ladder with a depth-2 queue: saturation is reachable by
    // a single flooding thread (submits are orders of magnitude faster
    // than a batch execution).
    let cheap = fams[2].clone();
    registry.load(&cheap, &opts(2)).unwrap();
    let ctl = TierController::new(Arc::clone(&registry), cfg_for(&fams[2..])).unwrap();

    let mut pending = Vec::new();
    let mut shed = 0u64;
    for i in 0..2000 {
        match ctl.route(image(i)) {
            Ok(rx) => pending.push(rx),
            Err(ServeError::Shed) => {
                shed += 1;
                if shed >= 8 {
                    break;
                }
            }
            Err(e) => panic!("unexpected routing error: {e}"),
        }
    }
    assert!(shed > 0, "a depth-2 queue flooded with 2000 requests must shed");
    assert_eq!(ctl.shed_count(), shed);
    // The drain guarantee is untouched by shedding: every accepted
    // request is answered exactly once.
    for rx in pending {
        let reply = rx
            .recv()
            .expect("accepted request must be answered despite shedding")
            .expect("accepted request resolves to a reply");
        assert_eq!(reply.logits.len(), CLASSES);
        assert!(rx.try_recv().is_err());
    }

    drop(ctl);
    if let Ok(r) = Arc::try_unwrap(registry) {
        r.shutdown();
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Draining the active tier out from under the controller: requests spill
/// past the dead tier with no control decision, `sample` senses it as
/// unhealthy, and the next `step` fails over immediately.
#[test]
fn drained_tier_spills_and_fails_over() {
    let (dir, fams) = ladder_fixture("drain");
    let registry = Arc::new(ModelRegistry::open(BackendSpec::native(&dir)));
    for f in &fams {
        registry.load(f, &opts(64)).unwrap();
    }
    let ctl = TierController::new(Arc::clone(&registry), cfg_for(&fams)).unwrap();

    // Baseline: all three tiers sense as healthy.
    let sensed = ctl.sample();
    assert_eq!(sensed.len(), fams.len());
    assert!(sensed.iter().all(|s| s.healthy));

    registry.drain_and_unload(&fams[0]).unwrap();

    // Routing spills past the drained tier immediately — the active
    // index has not moved, the request still gets answered.
    assert_eq!(ctl.active_tier(), 0);
    let reply = ctl.infer(image(7)).expect("request must spill to a live tier");
    assert_eq!(reply.logits.len(), CLASSES);

    // The next sensed epoch fails over without any dwell.
    match ctl.step() {
        TierDecision::Down { from: 0, to } => assert!(to >= 1),
        other => panic!("expected immediate failover down, got {other:?}"),
    }
    let last = ctl.trace().pop().expect("failover must be traced");
    assert_eq!(last.reason, "unhealthy");
    assert_eq!(ctl.active_tier_name(), &fams[last.to]);
    // The ladder keeps serving on the new tier.
    let reply = ctl.infer(image(8)).expect("failed-over tier serves");
    assert_eq!(reply.logits.len(), CLASSES);

    drop(ctl);
    if let Ok(r) = Arc::try_unwrap(registry) {
        r.shutdown();
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// Supervisor verdict feeding the control loop: a tier whose replicas
/// panic until the restart budget is exhausted flips unhealthy, and the
/// controller fails over on the very next sensed epoch — no dwell, no
/// hysteresis.
#[test]
fn restart_budget_exhaustion_fails_over_within_one_epoch() {
    use lsqnet::serve::{FaultPlan, FaultSpec, RestartPolicy};
    let (dir, fams) = ladder_fixture("budget");
    let registry = Arc::new(ModelRegistry::open(BackendSpec::native(&dir)));
    // Tier 0 panics on every dispatched batch and carries a 2-restart
    // budget: initial replica + 2 respawns = 3 failures, then give up.
    let plan = Arc::new(FaultPlan::new(&FaultSpec {
        seed: 11,
        horizon: 1 << 20,
        replica_panics: 1 << 20,
        ..FaultSpec::default()
    }));
    let mut doomed = opts(64);
    doomed.fault = Some(plan);
    doomed.restarts = RestartPolicy {
        budget: 2,
        window: Duration::from_secs(60),
        backoff: Duration::from_millis(1),
        backoff_cap: Duration::from_millis(2),
        jitter_seed: 0,
    };
    registry.load(&fams[0], &doomed).unwrap();
    for f in &fams[1..] {
        registry.load(f, &opts(64)).unwrap();
    }
    let ctl = TierController::new(Arc::clone(&registry), cfg_for(&fams)).unwrap();
    assert_eq!(ctl.active_tier(), 0);

    // Drive traffic at the doomed tier until the supervisor gives up.
    // Every accepted request still resolves (typed error), never drops.
    let deadline = std::time::Instant::now() + Duration::from_secs(20);
    let mut i = 0usize;
    while registry.healthy(&fams[0]).unwrap_or(false) {
        assert!(
            std::time::Instant::now() < deadline,
            "restart budget never exhausted"
        );
        if let Ok(rx) = ctl.route(image(i)) {
            assert!(rx.recv().is_ok(), "accepted request dropped during replica churn");
        }
        i += 1;
        std::thread::sleep(Duration::from_millis(1));
    }

    // The supervisor's verdict: restarts were spent, then health dropped.
    let stats = registry.stats(&fams[0]).unwrap();
    assert_eq!(stats.replica_restarts, 2, "budget of 2 respawns must be spent");
    assert!(stats.replica_failures >= 3, "initial replica + both respawns must fail");

    // One sensed epoch fails over — health preempts hysteresis.
    match ctl.step() {
        TierDecision::Down { from: 0, to } => assert!(to >= 1),
        other => panic!("expected immediate failover down, got {other:?}"),
    }
    let last = ctl.trace().pop().expect("failover must be traced");
    assert_eq!(last.reason, "unhealthy");
    // The ladder keeps serving on the surviving tiers.
    let reply = ctl.infer(image(9)).expect("failed-over tier serves");
    assert_eq!(reply.logits.len(), CLASSES);

    drop(ctl);
    if let Ok(r) = Arc::try_unwrap(registry) {
        r.shutdown();
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// The background driver runs real epochs on its own clock and stops
/// cleanly (thread joined) on `stop`.
#[test]
fn driver_runs_epochs_and_stops_cleanly() {
    let (dir, fams) = ladder_fixture("driver");
    let registry = Arc::new(ModelRegistry::open(BackendSpec::native(&dir)));
    for f in &fams {
        registry.load(f, &opts(64)).unwrap();
    }
    let mut cfg = cfg_for(&fams);
    cfg.epoch = Duration::from_millis(2);
    let ctl = Arc::new(TierController::new(Arc::clone(&registry), cfg).unwrap());
    let driver = ctl.start_driver().unwrap();
    // Real traffic while the driver senses in the background.
    for i in 0..16 {
        ctl.infer(image(i)).unwrap();
    }
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    while ctl.epochs() == 0 && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    driver.stop();
    let ran = ctl.epochs();
    assert!(ran > 0, "driver never completed an epoch");
    // Stopped means stopped: no further epochs accrue.
    std::thread::sleep(Duration::from_millis(20));
    assert_eq!(ctl.epochs(), ran);

    drop(ctl);
    if let Ok(r) = Arc::try_unwrap(registry) {
        r.shutdown();
    }
    std::fs::remove_dir_all(&dir).ok();
}
