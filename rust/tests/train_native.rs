//! End-to-end tests of the native (no-XLA) training subsystem: fixture
//! convergence at 2/3/4 bits for mlp + cnn_small, the acceptance run (mlp
//! at 3 bits to ≥90% train accuracy), the fp32-pretrain → quantized
//! fine-tune protocol with Section-2.1 step re-initialization, and
//! state/checkpoint invariants. These are the numbers EXPERIMENTS.md
//! §Train reports.

use std::path::PathBuf;

use lsqnet::config::{DataConfig, ExperimentConfig, Schedule};
use lsqnet::quant::lsq::{qrange, step_init};
use lsqnet::runtime::native::fixture::{write_synthetic_family, FixtureSpec};
use lsqnet::runtime::Manifest;
use lsqnet::train::NativeTrainer;

fn tmp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("lsq_train_native_{tag}_{}", std::process::id()))
}

/// A small-but-real training config over a fixture family in `dir`.
fn base_cfg(dir: &PathBuf, model: &str, bits: u32, name: &str) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.name = name.to_string();
    cfg.model = model.to_string();
    cfg.bits = bits;
    cfg.backend = "native".to_string();
    cfg.artifacts_dir = dir.to_string_lossy().to_string();
    cfg.out_dir = dir.join("runs").to_string_lossy().to_string();
    cfg.data = DataConfig {
        train_size: 128,
        test_size: 64,
        classes: 10,
        noise: 0.25,
        seed: 9,
        augment: false,
    };
    cfg.train.seed = 4;
    cfg.train.eval_every = 0; // final eval only — keep the tests fast
    cfg
}

/// Acceptance run: the synthetic-fixture mlp at 3 bits must reach ≥90%
/// train accuracy within the fixture budget (240 optimizer steps).
#[test]
fn mlp_q3_reaches_90pct_train_accuracy() {
    let dir = tmp_dir("mlp90");
    std::fs::remove_dir_all(&dir).ok();
    let spec = FixtureSpec { batch: 32, ..FixtureSpec::default() };
    write_synthetic_family(&dir, "mlp", 3, spec).unwrap();

    let mut cfg = base_cfg(&dir, "mlp", 3, "mlp_q3_native");
    cfg.train.epochs = 60; // 128/32 = 4 steps/epoch -> 240 steps
    cfg.train.lr = 0.02;
    cfg.train.weight_decay = 0.5e-4;
    cfg.train.schedule = Schedule::Cosine;

    let mut tr = NativeTrainer::new(cfg).unwrap();
    tr.verbose = false;
    let rep = tr.fit().unwrap();

    let steps = &rep.history.steps;
    assert!(steps.len() >= 200, "expected a full run, got {} steps", steps.len());
    let tail = &steps[steps.len() - 30..];
    let train_acc = tail.iter().map(|s| s.acc).sum::<f64>() / tail.len() as f64;
    assert!(
        train_acc >= 0.90,
        "mlp q3 train accuracy {train_acc:.3} < 0.90 over the last 30 steps"
    );
    assert!(rep.history.final_eval().is_some());
    assert!(rep.checkpoint.exists());
    std::fs::remove_dir_all(&dir).ok();
}

/// Convergence smoke across the quantized grid: for mlp and cnn_small at
/// 2/3/4 bits, 16 native optimizer steps must reduce the training loss
/// from its first-step value and keep everything finite.
#[test]
fn mlp_and_cnn_small_converge_at_2_3_4_bits() {
    for model in ["mlp", "cnn_small"] {
        let dir = tmp_dir(&format!("conv_{model}"));
        std::fs::remove_dir_all(&dir).ok();
        let spec = FixtureSpec { batch: 16, ..FixtureSpec::default() };
        for bits in [2u32, 3, 4] {
            write_synthetic_family(&dir, model, bits, spec).unwrap();
            let mut cfg = base_cfg(&dir, model, bits, &format!("{model}_q{bits}"));
            cfg.data.train_size = 64;
            cfg.data.test_size = 32;
            cfg.train.epochs = 4;
            cfg.train.max_steps = 16;
            cfg.train.lr = if model == "mlp" { 0.02 } else { 0.01 };
            let mut tr = NativeTrainer::new(cfg).unwrap();
            tr.verbose = false;
            let rep = tr.fit().unwrap();
            let steps = &rep.history.steps;
            assert_eq!(steps.len(), 16, "{model} q{bits}");
            let first = steps[0].loss;
            let recent = rep.history.recent_loss(4);
            assert!(
                recent < first,
                "{model} q{bits}: loss did not decrease ({first:.4} -> {recent:.4})"
            );
            assert!(steps.iter().all(|s| s.loss.is_finite()), "{model} q{bits}");
            assert!(rep.final_top1.is_finite(), "{model} q{bits}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// The paper protocol natively: fp32 pretrain, then quantized fine-tune
/// from that checkpoint with Section-2.1 step-size re-initialization —
/// `sw = 2⟨|w|⟩/√Qp` over the *loaded* weights, `sa` from the first batch.
#[test]
fn fp32_pretrain_then_quantized_finetune_reinits_steps() {
    let dir = tmp_dir("protocol");
    std::fs::remove_dir_all(&dir).ok();
    let spec = FixtureSpec { batch: 16, ..FixtureSpec::default() };
    // Both families merge into one fixture manifest.
    write_synthetic_family(&dir, "mlp", 32, spec).unwrap();
    let fam3 = write_synthetic_family(&dir, "mlp", 3, spec).unwrap();

    let mut cfg32 = base_cfg(&dir, "mlp", 32, "mlp_q32");
    cfg32.data.train_size = 64;
    cfg32.train.epochs = 1;
    cfg32.train.max_steps = 10;
    cfg32.train.lr = 0.02;
    let mut tr32 = NativeTrainer::new(cfg32).unwrap();
    tr32.verbose = false;
    let rep32 = tr32.fit().unwrap();
    assert!(rep32.checkpoint.exists());

    let mut cfg3 = base_cfg(&dir, "mlp", 3, "mlp_q3_ft");
    cfg3.data.train_size = 64;
    cfg3.train.epochs = 1;
    cfg3.train.max_steps = 4;
    cfg3.init_from = rep32.checkpoint.to_string_lossy().to_string();
    let tr3 = NativeTrainer::new(cfg3).unwrap();

    // The fine-tune state carries the pretrained weights and re-derived
    // step sizes (mlp layers are pinned to 8 bits: Qp = 127).
    let manifest = Manifest::load(&dir).unwrap();
    let fam = manifest.family(&fam3).unwrap();
    let w = tr3.state.param(fam, "fc1.w").unwrap().f32s().unwrap();
    let (_, qp) = qrange(8, true);
    let want_sw = step_init(w, qp);
    let sw = tr3.state.param(fam, "fc1.sw").unwrap().item_f32().unwrap();
    assert!(
        (sw - want_sw).abs() < 1e-6 * want_sw.abs().max(1e-6),
        "sw {sw} != 2<|w|>/sqrt(Qp) = {want_sw}"
    );
    for name in ["fc1.sa", "fc2.sa", "fc2.sw"] {
        let s = tr3.state.param(fam, name).unwrap().item_f32().unwrap();
        assert!(s > 0.0, "{name} = {s} not positive after init");
    }
    std::fs::remove_dir_all(&dir).ok();
}

/// One native step must touch everything a train step owns: parameters
/// move, momentum becomes non-zero, BN running stats leave their init, and
/// the step sizes themselves receive gradient (the paper's core claim).
#[test]
fn native_step_updates_params_momentum_bn_state_and_steps() {
    let dir = tmp_dir("stepfx");
    std::fs::remove_dir_all(&dir).ok();
    let spec = FixtureSpec { batch: 8, ..FixtureSpec::default() };
    write_synthetic_family(&dir, "cnn_small", 4, spec).unwrap();
    let mut cfg = base_cfg(&dir, "cnn_small", 4, "cnn_q4_step");
    cfg.data.train_size = 16;
    let mut tr = NativeTrainer::new(cfg).unwrap();
    tr.verbose = false;

    let manifest = Manifest::load(&dir).unwrap();
    let fam = manifest.family("cnn_small_q4").unwrap().clone();
    let sw_before = tr.state.param(&fam, "conv2.sw").unwrap().item_f32().unwrap();
    let w_before = tr.state.param(&fam, "conv2.w").unwrap().f32s().unwrap().to_vec();
    let rmean_before = tr.state.param(&fam, "bn1.rmean").unwrap().f32s().unwrap().to_vec();

    let ds = lsqnet::data::Dataset::train(&tr.cfg.data);
    let b = ds.batch_from_indices(&[0, 1, 2, 3, 4, 5, 6, 7], 8);
    let (loss, acc) = tr.step(b.x, b.y, 0.05, 1e-4).unwrap();
    assert!(loss.is_finite() && (0.0..=1.0).contains(&acc));
    assert_eq!(tr.state.step, 1);

    let sw_after = tr.state.param(&fam, "conv2.sw").unwrap().item_f32().unwrap();
    let w_after = tr.state.param(&fam, "conv2.w").unwrap().f32s().unwrap();
    let rmean_after = tr.state.param(&fam, "bn1.rmean").unwrap().f32s().unwrap();
    assert_ne!(sw_before, sw_after, "step size received no gradient");
    assert!(sw_after > 0.0);
    assert_ne!(w_before, w_after, "weights did not move");
    assert_ne!(rmean_before, rmean_after, "BN running mean not updated");
    assert!(tr.state.moms.iter().any(|m| {
        m.f32s().map(|v| v.iter().any(|&x| x != 0.0)).unwrap_or(false)
    }));
    std::fs::remove_dir_all(&dir).ok();
}

/// Checkpoint → reload → evaluate must be bit-stable: the saved fine-tune
/// state reloads into an identical eval (EXPERIMENTS.md §E2E item b).
#[test]
fn checkpoint_reloads_to_identical_eval() {
    let dir = tmp_dir("ckpt");
    std::fs::remove_dir_all(&dir).ok();
    let spec = FixtureSpec { batch: 16, ..FixtureSpec::default() };
    write_synthetic_family(&dir, "mlp", 4, spec).unwrap();
    let mut cfg = base_cfg(&dir, "mlp", 4, "mlp_q4_ck");
    cfg.data.train_size = 64;
    cfg.data.test_size = 32;
    cfg.train.epochs = 1;
    cfg.train.max_steps = 6;
    cfg.train.lr = 0.02;
    let mut tr = NativeTrainer::new(cfg.clone()).unwrap();
    tr.verbose = false;
    let rep = tr.fit().unwrap();
    let (l1, t1a, t5a) = tr.evaluate().unwrap();

    let mut cfg2 = cfg;
    cfg2.init_from = rep.checkpoint.to_string_lossy().to_string();
    let mut tr2 = NativeTrainer::new(cfg2).unwrap();
    tr2.verbose = false;
    let (l2, t1b, t5b) = tr2.evaluate().unwrap();
    assert_eq!(t1a, t1b);
    assert_eq!(t5a, t5b);
    assert!((l1 - l2).abs() < 1e-12, "{l1} vs {l2}");
    std::fs::remove_dir_all(&dir).ok();
}
